"""HQI — the paper's hybrid query index (Sections 4 + 5, end to end).

Build:  coarse k-means (m > 0 mode) → balanced qd-tree over attribute +
centroid cut predicates → one IVF index per leaf partition (√|Pᵢ| lists).

Batch search (Algorithm 3 across partitions):
  group by template → route template×partition via semantic descriptions
  (+ per-query centroid routing when m > 0) → per (partition, template):
  bitmap pushdown + planner work units (one matmul per posting-list group)
  → per-query merge across partitions.

Online search: same routing, per-query IVF scans (used standalone — the
"workload-aware index only" configuration of Section 6.5).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from . import kmeans as km
from .ivf import IVFIndex, ScanStats
from .planner import PlanConfig, batch_search_ivf
from .predicates import evaluate_filter
from .qdtree import QDTree, build_qdtree
from .types import SearchResult, VectorDatabase, Workload


@dataclasses.dataclass
class HQIConfig:
    m: int = 0  # query-to-centroid fan-out of Section 4.1.1 (0 = attrs only)
    n_coarse_centroids: int = 64  # coarse clustering for partitioning (m > 0)
    min_partition_size: int = 4096
    max_leaves: int = 1024
    ivf_centroids: Optional[int] = None  # default sqrt(|Pi|)
    kmeans_iters: int = 8
    cost_mode: str = "tuples"
    seed: int = 0
    plan: PlanConfig = dataclasses.field(default_factory=PlanConfig)


@dataclasses.dataclass
class Partition:
    rows: np.ndarray  # global tuple indices, aligned with ivf local order
    ivf: IVFIndex


@dataclasses.dataclass
class BuildInfo:
    qdtree_seconds: float = 0.0
    ivf_seconds: float = 0.0
    coarse_seconds: float = 0.0

    @property
    def total_seconds(self) -> float:
        return self.qdtree_seconds + self.ivf_seconds + self.coarse_seconds


class HQIIndex:
    def __init__(
        self,
        db: VectorDatabase,
        tree: QDTree,
        partitions: List[Partition],
        cfg: HQIConfig,
        coarse_centroids: Optional[np.ndarray],
        build_info: BuildInfo,
    ):
        self.db = db
        self.tree = tree
        self.partitions = partitions
        self.cfg = cfg
        self.coarse_centroids = coarse_centroids
        self.build_info = build_info
        self._bitmap_cache: Dict[tuple, np.ndarray] = {}

    # ------------------------------------------------------------------ build

    @staticmethod
    def build(db: VectorDatabase, workload_sample: Workload, cfg: HQIConfig = HQIConfig()) -> "HQIIndex":
        info = BuildInfo()
        centroid_of = None
        query_centroids = None
        coarse = None
        if cfg.m > 0:
            t0 = time.perf_counter()
            coarse = km.train_kmeans(
                db.vectors, cfg.n_coarse_centroids, iters=cfg.kmeans_iters, metric=db.metric, seed=cfg.seed
            )
            centroid_of = km.assign_kmeans(db.vectors, coarse, metric=db.metric)
            query_centroids = km.topm_centroids(
                workload_sample.vectors, coarse, cfg.m, metric=db.metric
            )
            info.coarse_seconds = time.perf_counter() - t0

        t0 = time.perf_counter()
        tree = build_qdtree(
            db,
            workload_sample,
            centroid_of=centroid_of,
            query_centroids=query_centroids,
            n_centroids=cfg.n_coarse_centroids if cfg.m > 0 else 0,
            min_size=cfg.min_partition_size,
            max_leaves=cfg.max_leaves,
            cost_mode=cfg.cost_mode,
        )
        info.qdtree_seconds = time.perf_counter() - t0

        t0 = time.perf_counter()
        partitions = []
        for leaf in tree.leaves:
            vecs = db.vectors[leaf.rows]
            nc = cfg.ivf_centroids or max(1, int(math.isqrt(len(leaf.rows))))
            ivf = IVFIndex.build(
                vecs, metric=db.metric, n_centroids=nc, kmeans_iters=cfg.kmeans_iters, seed=cfg.seed
            )
            partitions.append(Partition(rows=leaf.rows, ivf=ivf))
        info.ivf_seconds = time.perf_counter() - t0
        return HQIIndex(db, tree, partitions, cfg, coarse, info)

    # ----------------------------------------------------------------- common

    def template_bitmap(self, filt: tuple) -> np.ndarray:
        if filt not in self._bitmap_cache:
            self._bitmap_cache[filt] = evaluate_filter(filt, self.db)
        return self._bitmap_cache[filt]

    def clear_bitmap_cache(self):
        self._bitmap_cache.clear()

    def _routing(self, workload: Workload) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """(template_routes bool [T, L], query_centroid_ok bool [m, L] | None)."""
        troutes = np.stack([self.tree.route_filter(t) for t in workload.templates])
        qcent_ok = None
        if self.cfg.m > 0 and self.coarse_centroids is not None:
            allowed = self.tree.centroid_allowed()  # [L, nc]
            qc = km.topm_centroids(
                workload.vectors, self.coarse_centroids, self.cfg.m, metric=self.db.metric
            )  # [m, mfan]
            # query ok in leaf iff any of its m centroids is allowed there
            onehot = np.zeros((workload.m, allowed.shape[1]), dtype=bool)
            rows = np.repeat(np.arange(workload.m), qc.shape[1])
            onehot[rows, qc.reshape(-1)] = True
            qcent_ok = (onehot @ allowed.T.astype(np.int64)) > 0  # [m, L]
        return troutes, qcent_ok

    # ------------------------------------------------------------ batch search

    def search(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
        batch_vec: Union[bool, str] = True,
    ) -> SearchResult:
        """Batch HVQ processing (Algorithm 3 over the qd-tree partitions).

        batch_vec: True = always share posting-list matmuls; False = per-query
        scans; "auto" = the adaptive executor the paper's §6.5 calls for —
        batch a (template × partition) group only when it is large enough to
        amortize the work-unit padding (PlanConfig.adaptive_crossover).
        """
        m, k = workload.m, workload.k
        stats = ScanStats()
        troutes, qcent_ok = self._routing(workload)

        run_s = np.full((m, k), -np.inf, dtype=np.float32)
        run_i = np.full((m, k), -1, dtype=np.int64)

        def merge(qidx, s_new, i_new):
            cat_s = np.concatenate([run_s[qidx], s_new], axis=1)
            cat_i = np.concatenate([run_i[qidx], i_new], axis=1)
            part = np.argpartition(-cat_s, k - 1, axis=1)[:, :k]
            s_sel = np.take_along_axis(cat_s, part, axis=1)
            i_sel = np.take_along_axis(cat_i, part, axis=1)
            ordr = np.argsort(-s_sel, axis=1, kind="stable")
            run_s[qidx] = np.take_along_axis(s_sel, ordr, axis=1)
            run_i[qidx] = np.take_along_axis(i_sel, ordr, axis=1)

        for ti, filt in enumerate(workload.templates):
            q_of_t = workload.queries_for_template(ti)
            if len(q_of_t) == 0:
                continue
            bitmap = self.template_bitmap(filt)
            np_t = nprobe[ti] if isinstance(nprobe, dict) else nprobe
            for li in np.nonzero(troutes[ti])[0]:
                part = self.partitions[li]
                qidx = q_of_t
                if qcent_ok is not None:
                    qidx = q_of_t[qcent_ok[q_of_t, li]]
                if len(qidx) == 0:
                    continue
                local_bitmap = bitmap[part.rows]
                if not local_bitmap.any():
                    continue
                use_batch = (
                    len(qidx) >= self.cfg.plan.adaptive_crossover
                    if batch_vec == "auto"
                    else bool(batch_vec)
                )
                if use_batch:
                    s, loc = batch_search_ivf(
                        part.ivf,
                        workload.vectors[qidx],
                        nprobe=np_t,
                        k=k,
                        bitmap=local_bitmap,
                        stats=stats,
                        cfg=self.cfg.plan,
                    )
                else:
                    s = np.full((len(qidx), k), -np.inf, np.float32)
                    loc = np.full((len(qidx), k), -1, np.int64)
                    for r, qi in enumerate(qidx):
                        s[r], loc[r] = part.ivf.search_single(
                            workload.vectors[qi], nprobe=np_t, k=k, bitmap=local_bitmap, stats=stats
                        )
                gids = np.where(loc >= 0, part.rows[np.maximum(loc, 0)], -1)
                merge(qidx, s, gids)

        return SearchResult(ids=run_i, scores=run_s, tuples_scanned=stats.tuples_scanned)

    # ------------------------------------------------------------ online search

    def search_online(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
    ) -> SearchResult:
        """One query at a time (workload-aware index w/o batching, Section 6.5)."""
        return self.search(workload, nprobe=nprobe, batch_vec=False)

    # ------------------------------------------------------------------ stats

    def partition_sizes(self) -> np.ndarray:
        return np.array([len(p.rows) for p in self.partitions])

    def tuples_routed(self, workload: Workload) -> int:
        """Σ over (query, routed partition) of |partition| — the Eq.(1) cost."""
        troutes, qcent_ok = self._routing(workload)
        sizes = self.partition_sizes()
        total = 0
        for ti in range(len(workload.templates)):
            q_of_t = workload.queries_for_template(ti)
            for li in np.nonzero(troutes[ti])[0]:
                cnt = len(q_of_t)
                if qcent_ok is not None:
                    cnt = int(qcent_ok[q_of_t, li].sum())
                total += cnt * int(sizes[li])
        return total
