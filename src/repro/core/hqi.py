"""HQI — the paper's hybrid query index (Sections 4 + 5, end to end).

Build:  coarse k-means (m > 0 mode) → balanced qd-tree over attribute +
centroid cut predicates → one IVF index per leaf partition (√|Pᵢ| lists) →
one index-wide ``PackedArena`` concatenating every partition's posting lists.

Batch search (Algorithm 3 across partitions) is a two-stage plan/execute
engine over the whole workload:

  * ``Router`` (the routing layer): template → partition routes via semantic
    descriptions, per-query centroid gating when m > 0, and the template
    bitmap cache — all the host-side pruning of Sections 4.1.3 / 4.2.
  * Stage 1 (core/plan.py): every routed (template × partition) product
    becomes an ``EngineTask``; ``build_plan`` buckets ALL resulting
    (query-chunk × posting-list) work units globally by padded shape, under
    the ``PlanConfig.max_bucket_shapes`` compile-shape budget.
  * Stage 2 (core/planner.py): each bucket executes as ONE megabatched
    kernel dispatch through the arena, and the cross-partition merge is one
    device-side segmented top-k. With ``scan_mode="pq"`` the scan stage runs
    over the arena's uint8 PQ codes (ADC) and a single exact re-rank dispatch
    recovers recall — same plan, d·4/M× less scan traffic.

Kernel dispatches per workload are therefore O(#buckets) ≤
``max_bucket_shapes`` instead of O(templates × partitions).

Sharded execution: with ``HQIConfig.mesh`` set, stage 2 runs across the
device mesh (``core/distributed.execute_sharded``) — the arena shards over
the model axis, each rank executes its own bucket slice, and the only
cross-rank traffic is the O(k·|model|) per-query candidate gather. Results
stay bit-identical to the single-device engine.

Online search: same routing, per-query IVF scans (used standalone — the
"workload-aware index only" configuration of Section 6.5). The "auto" mode
is the paper's adaptive executor: small (template × partition) groups take
the per-query path, everything else joins the global plan, and both feed the
same final merge.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..obs.trace import get_tracer
from . import kmeans as km
from .arena import PackedArena
from .ivf import IVFIndex, ScanStats
from .plan import EngineTask, PlanConfig, build_plan
from .planner import ExtraCandidates, execute_plan
from .pq import PQCodebook, train_pq
from .predicates import evaluate_filter, filter_from_state, filter_to_state
from .qdtree import QDTree, build_qdtree
from .types import SearchResult, VectorDatabase, Workload


@dataclasses.dataclass
class HQIConfig:
    m: int = 0  # query-to-centroid fan-out of Section 4.1.1 (0 = attrs only)
    n_coarse_centroids: int = 64  # coarse clustering for partitioning (m > 0)
    min_partition_size: int = 4096
    max_leaves: int = 1024
    ivf_centroids: Optional[int] = None  # default sqrt(|Pi|)
    kmeans_iters: int = 8
    cost_mode: str = "tuples"
    seed: int = 0
    plan: PlanConfig = dataclasses.field(default_factory=PlanConfig)
    # compressed execution (engine knobs, mirrored into ``plan`` when set):
    # scan_mode="pq" trains an index-wide PQ codebook at build time, stores
    # uint8 codes in the arena, and runs the ADC scan -> exact re-rank path
    scan_mode: Optional[str] = None  # None = keep plan.scan_mode
    refine_factor: Optional[int] = None  # None = keep plan.refine_factor
    pq_m: int = 8  # PQ subspaces (d must be divisible; d·4/M× compression)
    # sharded execution: a jax Mesh routes every engine-backed search through
    # core/distributed.execute_sharded — the arena shards over the mesh's
    # model axis and cross-rank traffic is the O(k·|model|) candidate gather.
    # Results are bit-identical to mesh=None (tests/test_engine_sharded.py).
    mesh: Optional[object] = None  # jax.sharding.Mesh (opaque: core stays numpy)
    shard_spec: Optional[object] = None  # core.distributed.ShardSpec

    def __post_init__(self):
        # replace, never mutate: the caller may share one PlanConfig across
        # HQIConfigs, and flipping its scan_mode in place would silently
        # switch sibling indexes onto a path they have no codebook for
        if self.scan_mode is not None:
            self.plan = dataclasses.replace(self.plan, scan_mode=self.scan_mode)
        if self.refine_factor is not None:
            self.plan = dataclasses.replace(
                self.plan, refine_factor=int(self.refine_factor)
            )

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py). ``mesh``/``shard_spec`` are
        runtime wiring (device handles), not index state — a loaded index
        re-attaches them explicitly."""
        state = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("plan", "mesh", "shard_spec")
        }
        state["plan"] = dataclasses.asdict(self.plan)
        return state

    @staticmethod
    def from_state(state: dict) -> "HQIConfig":
        kw = dict(state)
        kw["plan"] = PlanConfig(**kw["plan"])
        return HQIConfig(**kw)


@dataclasses.dataclass
class Partition:
    rows: np.ndarray  # global tuple indices, aligned with ivf local order
    ivf: IVFIndex


@dataclasses.dataclass
class BuildInfo:
    qdtree_seconds: float = 0.0
    ivf_seconds: float = 0.0
    coarse_seconds: float = 0.0
    pq_seconds: float = 0.0  # codebook training (scan_mode="pq" only)

    @property
    def total_seconds(self) -> float:
        return (
            self.qdtree_seconds + self.ivf_seconds + self.coarse_seconds
            + self.pq_seconds
        )


class Router:
    """The routing layer: which (template, query) reaches which partition.

    Owns the qd-tree semantic-description routing (Section 4.1.3), the
    per-query centroid gating of the m > 0 mode, and the template bitmap
    cache (Section 4.2) — everything the engine needs to turn a workload
    into ``EngineTask``s.
    """

    def __init__(
        self,
        db: VectorDatabase,
        tree: QDTree,
        coarse_centroids: Optional[np.ndarray],
        m_fanout: int,
    ):
        self.db = db
        self.tree = tree
        self.coarse_centroids = coarse_centroids
        self.m_fanout = m_fanout
        self._bitmap_cache: Dict[tuple, np.ndarray] = {}

    def template_bitmap(self, filt: tuple) -> np.ndarray:
        if filt not in self._bitmap_cache:
            self._bitmap_cache[filt] = evaluate_filter(filt, self.db)
        return self._bitmap_cache[filt]

    def clear_cache(self) -> None:
        self._bitmap_cache.clear()

    def routes(self, workload: Workload) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """(template_routes bool [T, L], query_centroid_ok bool [m, L] | None)."""
        troutes = np.stack([self.tree.route_filter(t) for t in workload.templates])
        qcent_ok = None
        if self.m_fanout > 0 and self.coarse_centroids is not None:
            allowed = self.tree.centroid_allowed()  # [L, nc]
            qc = km.topm_centroids(
                workload.vectors, self.coarse_centroids, self.m_fanout, metric=self.db.metric
            )  # [m, mfan]
            # query ok in leaf iff any of its m centroids is allowed there
            onehot = np.zeros((workload.m, allowed.shape[1]), dtype=bool)
            rows = np.repeat(np.arange(workload.m), qc.shape[1])
            onehot[rows, qc.reshape(-1)] = True
            qcent_ok = (onehot @ allowed.T.astype(np.int64)) > 0  # [m, L]
        return troutes, qcent_ok


class HQIIndex:
    def __init__(
        self,
        db: VectorDatabase,
        tree: QDTree,
        partitions: List[Partition],
        cfg: HQIConfig,
        coarse_centroids: Optional[np.ndarray],
        build_info: BuildInfo,
        pq: Optional[PQCodebook] = None,
    ):
        self.db = db
        self.tree = tree
        self.partitions = partitions
        self.cfg = cfg
        self.coarse_centroids = coarse_centroids
        self.build_info = build_info
        self.pq = pq  # index-wide codebook (scan_mode="pq")
        self.router = Router(db, tree, coarse_centroids, cfg.m)
        self._arena: Optional[PackedArena] = None
        self._sharded = None  # ShardedArena views, keyed off the live arena

    @property
    def arena(self) -> PackedArena:
        """Index-wide packed arena, materialized on first engine-backed search
        (the per-query-only configuration never pays the concatenation). When
        a codebook was trained at build time the arena also carries uint8 PQ
        codes for the engine's compressed scan stage."""
        if self._arena is None:
            self._arena = PackedArena.from_partitions(
                [(p.rows, p.ivf) for p in self.partitions], pq=self.pq
            )
        return self._arena

    def sharded_arena(self, n_shards: int):
        """Per-rank views of the arena for ``cfg.mesh`` searches, memoized
        until the arena itself is invalidated (views stay aliased to it)."""
        if self._sharded is None or self._sharded.n_shards != int(n_shards):
            self._sharded = self.arena.shard(int(n_shards))
        return self._sharded

    def attach_pq(self, pq: PQCodebook) -> None:
        """Attach a codebook to an index built without one (scan_mode="f32").

        Enables per-call ``search(scan_mode="pq")`` overrides — the serving
        layer's overload degradation — while default searches stay exact. An
        already-materialized arena is re-encoded in place; shard views are
        invalidated (they alias the arena's code planes).
        """
        self.pq = pq
        if self._arena is not None:
            self._arena.attach_pq(pq)
            self._sharded = None

    # ------------------------------------------------------------------ build

    @staticmethod
    def build(
        db: VectorDatabase, workload_sample: Workload, cfg: Optional[HQIConfig] = None
    ) -> "HQIIndex":
        cfg = HQIConfig() if cfg is None else cfg
        info = BuildInfo()
        centroid_of = None
        query_centroids = None
        coarse = None
        if cfg.m > 0:
            t0 = time.perf_counter()
            coarse = km.train_kmeans(
                db.vectors, cfg.n_coarse_centroids, iters=cfg.kmeans_iters, metric=db.metric, seed=cfg.seed
            )
            centroid_of = km.assign_kmeans(db.vectors, coarse, metric=db.metric)
            query_centroids = km.topm_centroids(
                workload_sample.vectors, coarse, cfg.m, metric=db.metric
            )
            info.coarse_seconds = time.perf_counter() - t0

        t0 = time.perf_counter()
        tree = build_qdtree(
            db,
            workload_sample,
            centroid_of=centroid_of,
            query_centroids=query_centroids,
            n_centroids=cfg.n_coarse_centroids if cfg.m > 0 else 0,
            min_size=cfg.min_partition_size,
            max_leaves=cfg.max_leaves,
            cost_mode=cfg.cost_mode,
        )
        info.qdtree_seconds = time.perf_counter() - t0

        t0 = time.perf_counter()
        partitions = []
        for leaf in tree.leaves:
            vecs = db.vectors[leaf.rows]
            nc = cfg.ivf_centroids or max(1, int(math.isqrt(len(leaf.rows))))
            ivf = IVFIndex.build(
                vecs, metric=db.metric, n_centroids=nc, kmeans_iters=cfg.kmeans_iters, seed=cfg.seed
            )
            partitions.append(Partition(rows=leaf.rows, ivf=ivf))
        info.ivf_seconds = time.perf_counter() - t0

        pq_cb = None
        if cfg.plan.scan_mode == "pq":
            t0 = time.perf_counter()
            assert db.d % cfg.pq_m == 0, (
                f"scan_mode='pq': d={db.d} not divisible by pq_m={cfg.pq_m}"
            )
            pq_cb = train_pq(
                db.vectors, cfg.pq_m, metric=db.metric,
                iters=cfg.kmeans_iters, seed=cfg.seed,
            )
            info.pq_seconds = time.perf_counter() - t0
        return HQIIndex(db, tree, partitions, cfg, coarse, info, pq=pq_cb)

    # ------------------------------------------------------------ batch search

    def _engine_tasks(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]],
        batch_vec: Union[bool, str],
        stats: ScanStats,
        live_mask: Optional[np.ndarray] = None,
    ) -> Tuple[List[EngineTask], List[ExtraCandidates], Dict[int, int]]:
        """Route the workload into engine tasks + host-side per-query scans.

        Every routed (template × partition) product with a non-empty bitmap
        either joins the global plan (``EngineTask``) or — when the adaptive
        executor deems the group too small to amortize padding — runs as
        per-query scans whose top-ks are returned as extra merge candidates.
        The third return is the probe-heat map {partition: #queries routed
        there} across both paths (the drift monitor's per-partition feed).

        ``live_mask`` (bool [db.n]) is the serving layer's tombstone filter:
        it is ANDed into every template bitmap *after* the cache lookup, so
        deletes never invalidate the Router's bitmap cache.
        """
        troutes, qcent_ok = self.router.routes(workload)
        tasks: List[EngineTask] = []
        extra: List[ExtraCandidates] = []
        part_probes: Dict[int, int] = {}
        k = workload.k
        for ti, filt in enumerate(workload.templates):
            q_of_t = workload.queries_for_template(ti)
            if len(q_of_t) == 0:
                continue
            bitmap = self.router.template_bitmap(filt)
            if live_mask is not None:
                bitmap = bitmap & live_mask
            np_t = nprobe[ti] if isinstance(nprobe, dict) else nprobe
            for li in np.nonzero(troutes[ti])[0]:
                part = self.partitions[li]
                qidx = q_of_t
                if qcent_ok is not None:
                    qidx = q_of_t[qcent_ok[q_of_t, li]]
                if len(qidx) == 0:
                    continue
                local_bitmap = bitmap[part.rows]
                if not local_bitmap.any():
                    continue
                li_key = int(li)
                part_probes[li_key] = part_probes.get(li_key, 0) + len(qidx)
                use_batch = (
                    len(qidx) >= self.cfg.plan.adaptive_crossover
                    if batch_vec == "auto"
                    else bool(batch_vec)
                )
                if use_batch:
                    packed = None
                    if not local_bitmap.all():
                        packed = self.arena.packed_bitmap(int(li), local_bitmap)
                    tasks.append(
                        EngineTask(
                            part=int(li),
                            qrows=qidx.astype(np.int64),
                            nprobe=int(np_t),
                            packed_bitmap=packed,
                        )
                    )
                else:
                    s, loc = part.ivf.search_group(
                        workload.vectors[qidx], nprobe=np_t, k=k,
                        bitmap=local_bitmap, stats=stats,
                    )
                    gids = np.where(loc >= 0, part.rows[np.maximum(loc, 0)], -1)
                    extra.append((qidx.astype(np.int64), s, gids))
        return tasks, extra, part_probes

    def search(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
        batch_vec: Union[bool, str] = True,
        live_mask: Optional[np.ndarray] = None,
        scan_mode: Optional[str] = None,
        refine_factor: Optional[int] = None,
    ) -> SearchResult:
        """Batch HVQ processing: one global plan, megabatched dispatch.

        batch_vec: True = all vector work through the engine (at most
        ``PlanConfig.max_bucket_shapes`` kernel dispatches per workload);
        False = per-query scans; "auto" = the adaptive executor the paper's
        §6.5 calls for — a (template × partition) group joins the global plan
        only when it is large enough to amortize the work-unit padding
        (PlanConfig.adaptive_crossover).

        live_mask: optional bool [db.n] of rows still alive — the serving
        layer's tombstones; dead rows are excluded from every result exactly.

        scan_mode / refine_factor: per-call overrides of the build-time plan
        config — the serving layer's overload degradation sheds an exact f32
        deployment to ``scan_mode="pq"`` per flush without touching the
        index. ``scan_mode="pq"`` requires a codebook (``attach_pq`` can add
        one to an f32-built index).
        """
        plan_cfg = self.cfg.plan
        if scan_mode is not None or refine_factor is not None:
            if (scan_mode or plan_cfg.scan_mode) == "pq":
                assert self.pq is not None, (
                    "scan_mode='pq' override needs a codebook — "
                    "HQIIndex.attach_pq() first"
                )
            plan_cfg = dataclasses.replace(
                plan_cfg,
                scan_mode=plan_cfg.scan_mode if scan_mode is None else scan_mode,
                refine_factor=(
                    plan_cfg.refine_factor
                    if refine_factor is None
                    else int(refine_factor)
                ),
            )
        m, k = workload.m, workload.k
        stats = ScanStats()
        tracer = get_tracer()
        with tracer.span("engine.route", m=m, templates=len(workload.templates)):
            tasks, extra, part_probes = self._engine_tasks(
                workload, nprobe=nprobe, batch_vec=batch_vec, stats=stats,
                live_mask=live_mask,
            )
        shard_stats = None
        if tasks and self.cfg.mesh is not None:
            # sharded engine: same tasks, same routing, device-mesh execution
            from .distributed import ShardSpec, execute_sharded

            spec = self.cfg.shard_spec or ShardSpec()
            with tracer.span("plan.execute", mode="sharded", tasks=len(tasks)):
                run_s, run_i, shard_stats = execute_sharded(
                    self.sharded_arena(spec.n_shards(self.cfg.mesh)),
                    tasks,
                    workload.vectors,
                    mesh=self.cfg.mesh,
                    spec=spec,
                    m=m,
                    k=k,
                    cfg=plan_cfg,
                    extra=extra,
                    stats=stats,
                )
        else:
            # the all-per-query path (batch_vec=False) never touches the arena
            arena = self.arena if tasks else None
            with tracer.span("plan.build", tasks=len(tasks)):
                plan = build_plan(
                    arena, tasks, workload.vectors, m=m, k=k, cfg=plan_cfg, stats=stats
                )
            with tracer.span(
                "plan.execute", buckets=len(plan.buckets), extras=len(extra)
            ):
                run_s, run_i = execute_plan(
                    plan, arena, workload.vectors, cfg=plan_cfg, extra=extra, stats=stats
                )
        return SearchResult(
            ids=run_i,
            scores=run_s,
            tuples_scanned=stats.tuples_scanned,
            bytes_scanned=stats.bytes_scanned,
            peak_candidate_bytes=stats.peak_candidate_bytes,
            lut_bytes=stats.lut_bytes,
            shard_stats=shard_stats,
            part_probes=part_probes,
        )

    # ------------------------------------------------------------ online search

    def search_online(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
        live_mask: Optional[np.ndarray] = None,
    ) -> SearchResult:
        """One query at a time (workload-aware index w/o batching, Section 6.5)."""
        return self.search(workload, nprobe=nprobe, batch_vec=False, live_mask=live_mask)

    # ------------------------------------------------------------ live updates

    def invalidate_caches(self) -> None:
        """Drop every derived structure that depends on DB contents.

        The serving layer calls this after any mutation that changes row
        count or vector contents: the Router's template bitmaps are length-
        [db.n] and the arena holds a copy of every partition's packed
        vectors, so both must be rebuilt. (Pure deletes don't need this —
        they flow through ``live_mask`` at search time.)
        """
        self.router.clear_cache()
        self._arena = None
        self._sharded = None

    def extend(self, new_db: VectorDatabase) -> np.ndarray:
        """Fold freshly inserted tuples into the existing partitioning.

        The serving layer's ``refresh()`` path: routes each new tuple to its
        unique qd-tree leaf (semantic-description membership, no Algorithm-1
        re-run), assigns it to that partition's nearest existing posting list
        (``IVFIndex.extend`` — no k-means), and incrementally rebuilds the
        arena reusing unchanged partitions. The qd-tree structure itself is a
        build-time artifact mined from the historical workload and is kept.

        Returns the new tuples' global row ids (``old_n .. old_n + new - 1``).
        The Router bitmap cache is always invalidated (bitmaps are [db.n]).
        """
        n0 = self.db.n
        new_rows = n0 + np.arange(new_db.n, dtype=np.int64)
        if new_db.n == 0:
            return new_rows
        cent_new = None
        if self.cfg.m > 0 and self.coarse_centroids is not None:
            cent_new = km.assign_kmeans(
                new_db.vectors, self.coarse_centroids, metric=self.db.metric
            )
        leaf_of = self.tree.route_tuples(new_db, cent_new)
        self.db = VectorDatabase.concat(self.db, new_db)
        self.router.db = self.db
        self.router.clear_cache()
        changed = []
        for li in np.unique(leaf_of):
            li = int(li)
            idx = np.nonzero(leaf_of == li)[0]
            part = self.partitions[li]
            self.partitions[li] = Partition(
                rows=np.concatenate([part.rows, new_rows[idx]]),
                ivf=part.ivf.extend(new_db.vectors[idx]),
            )
            # keep the build-time alias (Partition.rows IS the leaf's row set)
            self.tree.leaves[li].rows = self.partitions[li].rows
            changed.append(li)
        if self._arena is not None:
            self._arena = PackedArena.updated(
                self._arena, [(p.rows, p.ivf) for p in self.partitions], changed
            )
        self._sharded = None  # shard views alias the replaced arena
        return new_rows

    # ------------------------------------------------------------ persistence

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): everything a warm restart
        needs — DB columns, qd-tree, per-partition IVFs, coarse centroids,
        PQ codebook, the materialized arena (rows + posting-list table +
        uint8 codes), and the Router's template bitmap cache — so a loaded
        index answers bit-identically to this one with no recompute.
        """
        cached = list(self.router._bitmap_cache.items())
        return {
            "cfg": self.cfg.to_state(),
            "db": self.db.to_state(),
            "tree": self.tree.to_state(),
            "partitions": [
                {"rows": p.rows, "ivf": p.ivf.to_state()} for p in self.partitions
            ],
            "coarse_centroids": self.coarse_centroids,
            "pq": None if self.pq is None else self.pq.to_state(),
            "build_info": dataclasses.asdict(self.build_info),
            # materialize so the snapshot serves engine searches immediately
            # after load (no O(N·d) concatenation / O(N·M) re-encode)
            "arena": self.arena.to_state(),
            "router_cache": {
                "filters": [filter_to_state(f) for f, _ in cached],
                "bitmaps": (
                    np.stack([bm for _, bm in cached])
                    if cached
                    else np.zeros((0, self.db.n), dtype=bool)
                ),
            },
        }

    @staticmethod
    def from_state(state: dict) -> "HQIIndex":
        index = HQIIndex(
            db=VectorDatabase.from_state(state["db"]),
            tree=QDTree.from_state(state["tree"]),
            partitions=[
                Partition(rows=np.asarray(ps["rows"]), ivf=IVFIndex.from_state(ps["ivf"]))
                for ps in state["partitions"]
            ],
            cfg=HQIConfig.from_state(state["cfg"]),
            coarse_centroids=(
                None
                if state["coarse_centroids"] is None
                else np.asarray(state["coarse_centroids"])
            ),
            build_info=BuildInfo(**state["build_info"]),
            pq=None if state["pq"] is None else PQCodebook.from_state(state["pq"]),
        )
        index._arena = PackedArena.from_state(state["arena"])
        cache = state["router_cache"]
        bitmaps = np.asarray(cache["bitmaps"])
        for fi, fs in enumerate(cache["filters"]):
            index.router._bitmap_cache[filter_from_state(fs)] = bitmaps[fi]
        return index

    # ------------------------------------------------------------------ stats

    def partition_sizes(self) -> np.ndarray:
        return np.array([len(p.rows) for p in self.partitions])

    def tuples_routed(self, workload: Workload) -> int:
        """Σ over (query, routed partition) of |partition| — the Eq.(1) cost."""
        troutes, qcent_ok = self.router.routes(workload)
        sizes = self.partition_sizes()
        total = 0
        for ti in range(len(workload.templates)):
            q_of_t = workload.queries_for_template(ti)
            for li in np.nonzero(troutes[ti])[0]:
                cnt = len(q_of_t)
                if qcent_ok is not None:
                    cnt = int(qcent_ok[q_of_t, li].sum())
                total += cnt * int(sizes[li])
        return total
