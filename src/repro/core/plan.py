"""Stage 1 of the execution engine: one global plan for the whole workload.

The old path walked a Python loop over every (template × partition) pair and
packed work units separately for each, so host-side packing, kernel dispatch
count, and XLA compile-cache pressure all scaled with T×L. ``build_plan``
instead takes every routed (template × partition) product as an
``EngineTask`` and buckets ALL resulting (query-chunk × posting-list) work
units *globally* by padded shape — posting lists from different partitions
and templates land in the same bucket whenever their padded length matches,
and each bucket later executes as ONE kernel dispatch (planner.py).

Addressing is index-wide: work units reference posting lists by their global
id in a ``PackedArena``, so a single gather serves every partition.

``PlanConfig.max_bucket_shapes`` is the compile-shape budget: when the
workload would need more distinct padded lengths than that, the smallest pads
are rounded up into the surviving ladder, so the number of compiled kernels
(and dispatches) is bounded regardless of workload shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .arena import PackedArena, ShardedArena
from .ivf import ScanStats


def _next_pow2(x: int, lo: int = 32) -> int:
    return max(lo, 1 << max(0, x - 1).bit_length())


@dataclasses.dataclass
class PlanConfig:
    tq_unit: int = 64  # queries per work unit
    min_list_pad: int = 32  # smallest padded list bucket
    max_bucket_shapes: int = 8  # compile-shape budget: max distinct padded lengths
    use_pallas: Optional[bool] = None  # None = ops default
    interpret: Optional[bool] = None
    # adaptive executor (paper §6.5): below this group size the per-query
    # scan beats batched matmuls (Fig. 7a's crossover ≈ 100 at paper scale)
    adaptive_crossover: int = 64
    # compressed execution: "f32" streams raw vectors (exact); "pq" runs the
    # two-stage ADC scan -> exact re-rank over the arena's uint8 PQ codes,
    # cutting scan HBM traffic by d·4/M× at a small recall cost
    scan_mode: str = "f32"
    # ADC candidates kept per query = refine_factor · k; the exact re-rank
    # recovers recall lost to quantization (FAISS's "refine" stage)
    refine_factor: int = 4
    # candidate merge layout: "segmented" (default) scatters per-unit top-ks
    # into a flat CSR-style [Σ segments, k] buffer reduced by one ragged
    # merge — peak merge memory tracks the REAL per-query slot counts, and
    # the compressed scan indexes the resident LUT table directly (no
    # [W, TQ, M, 256] expansion). "dense" keeps the rectangular
    # [m, n_slots, k] tensor sized by the widest query (the comparison
    # baseline the parity suite and the skewed-memory bench run against).
    merge_layout: str = "segmented"


@dataclasses.dataclass
class EngineTask:
    """One routed (template × partition) product, in arena coordinates."""

    part: int  # arena partition id
    qrows: np.ndarray  # i64 — workload query rows routed here
    nprobe: int
    packed_bitmap: Optional[np.ndarray]  # bool, partition-packed order; None = all pass


@dataclasses.dataclass
class WorkUnit:
    """A (query-chunk × posting-list) pair, shaped (tq, padded list len)."""

    task: int  # index into ExecutionPlan.tasks (bitmap lookup at exec time)
    glist: int  # global posting-list id in the arena
    qrows: np.ndarray  # i64 [<=tq] — workload query rows
    slots: np.ndarray  # i64 [<=tq] — per-query output slot in the merge tensor


@dataclasses.dataclass
class ExecutionPlan:
    """The whole workload's vector work, bucketed for megabatched dispatch."""

    tasks: List[EngineTask]
    buckets: Dict[int, List[WorkUnit]]  # padded list len -> units (tq fixed)
    tq: int
    m: int  # workload queries
    k: int
    n_slots: int  # candidate slots per query in the DENSE merge tensor (max)
    # per-query REAL slot counts (seg_counts[q] slots were assigned to query
    # q; n_slots == seg_counts.max()): the segmented executor's CSR segment
    # widths, so its flat candidate buffer holds Σ seg_counts rows instead of
    # m·n_slots
    seg_counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )

    @property
    def n_units(self) -> int:
        return sum(len(u) for u in self.buckets.values())

    @property
    def n_dispatches(self) -> int:
        """Kernel dispatches stage 2 will issue — one per bucket."""
        return len(self.buckets)


def build_plan(
    arena: Optional[PackedArena],  # None allowed iff tasks is empty
    tasks: List[EngineTask],
    q_vecs: np.ndarray,  # f32 [m, d] — the workload's query vectors
    *,
    m: int,
    k: int,
    cfg: Optional[PlanConfig] = None,
    stats: Optional[ScanStats] = None,
) -> ExecutionPlan:
    """Route every task through its partition's quantizer and bucket globally.

    Each query receives one output *slot* per probed posting list (slot ids
    are dense per query, across all tasks); the executor scatters unit top-ks
    into a [m, n_slots, k] candidate tensor and reduces it in one device op.
    """
    cfg = PlanConfig() if cfg is None else cfg
    tq = cfg.tq_unit
    next_slot = np.zeros(m, dtype=np.int64)
    raw: Dict[int, List[WorkUnit]] = {}

    for t_id, task in enumerate(tasks):
        mt = len(task.qrows)
        if mt == 0:
            continue
        probes = arena.probe(task.part, q_vecs[task.qrows], task.nprobe)  # [mt, np_eff]
        np_eff = probes.shape[1]
        slot_base = next_slot[task.qrows].copy()
        next_slot[task.qrows] += np_eff

        # invert (query, probe-slot) -> per-list query groups
        flat_list = probes.reshape(-1).astype(np.int64)
        flat_q = np.repeat(np.arange(mt, dtype=np.int64), np_eff)
        flat_slot = np.tile(np.arange(np_eff, dtype=np.int64), mt)
        sort = np.argsort(flat_list, kind="stable")
        flat_list, flat_q, flat_slot = flat_list[sort], flat_q[sort], flat_slot[sort]
        uniq, group_starts = np.unique(flat_list, return_index=True)
        group_ends = np.append(group_starts[1:], len(flat_list))

        part_row0 = int(arena.part_row[task.part])
        for g, gs, ge in zip(uniq, group_starts, group_ends):
            llen = int(arena.list_len[g])
            if llen == 0:
                continue
            nq_group = int(ge - gs)
            if task.packed_bitmap is not None:
                s0 = int(arena.list_start[g]) - part_row0
                n_live = int(task.packed_bitmap[s0 : s0 + llen].sum())
            else:
                n_live = llen
            if stats is not None:
                stats.tuples_scanned += llen * nq_group
                stats.dists_computed += n_live * nq_group
            if n_live == 0:
                continue  # bitmap kills the whole list: scanned, no distances
            lp = _next_pow2(llen, cfg.min_list_pad)
            qs, slots = flat_q[gs:ge], flat_slot[gs:ge]
            for cs in range(0, nq_group, tq):
                raw.setdefault(lp, []).append(
                    WorkUnit(
                        task=t_id,
                        glist=int(g),
                        qrows=task.qrows[qs[cs : cs + tq]],
                        slots=slot_base[qs[cs : cs + tq]] + slots[cs : cs + tq],
                    )
                )

    buckets = _coalesce_shapes(raw, cfg.max_bucket_shapes)
    return ExecutionPlan(
        tasks=tasks,
        buckets=buckets,
        tq=tq,
        m=m,
        k=k,
        n_slots=int(next_slot.max()) if m else 0,
        seg_counts=next_slot,  # final per-query slot counts = segment widths
    )


@dataclasses.dataclass
class ShardedPlan:
    """The single-device plan, with every work unit routed to its owner rank.

    ``plan`` is the EXACT ``build_plan`` output a single device would execute
    — same probes, same buckets, same slot numbering, same compile-shape
    ladder — so sharded execution inherits its correctness structurally.
    ``rank_buckets[r]`` holds rank r's share of each bucket: a unit lands on
    the rank that stores its posting list, every unit lands on exactly one
    rank, and each shared pad executes as ONE collective dispatch with all
    ranks' units stacked along the mesh axis.
    """

    plan: ExecutionPlan  # the workload's single-device plan, reused verbatim
    rank_buckets: List[Dict[int, List[WorkUnit]]]  # per rank: pad -> units

    @property
    def n_shards(self) -> int:
        return len(self.rank_buckets)

    @property
    def pads(self) -> List[int]:
        return sorted(self.plan.buckets)

    @property
    def per_rank_units(self) -> np.ndarray:
        return np.array(
            [sum(len(u) for u in rb.values()) for rb in self.rank_buckets],
            dtype=np.int64,
        )

    @property
    def n_units(self) -> int:
        return self.plan.n_units

    @property
    def n_dispatches(self) -> int:
        """Sharded kernel dispatches stage 2 will issue — one per shared pad."""
        return self.plan.n_dispatches


def build_plan_sharded(
    sharded: ShardedArena,
    tasks: List[EngineTask],
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    m: int,
    k: int,
    cfg: Optional[PlanConfig] = None,
    stats: Optional[ScanStats] = None,
) -> ShardedPlan:
    """Shard-aware stage 1: plan once, route work units to owner ranks.

    Probing, list grouping, query chunking, slot assignment, scan accounting,
    and shape coalescing all run through the single-device ``build_plan`` —
    sharding only PARTITIONS the resulting unit set by posting-list owner, so
    per-rank unit counts always sum to the single-device plan's (a property
    the hypothesis suite asserts) and the mesh shares one shape ladder.
    """
    plan = build_plan(sharded.base, tasks, q_vecs, m=m, k=k, cfg=cfg, stats=stats)
    R = sharded.n_shards
    rank_buckets: List[Dict[int, List[WorkUnit]]] = [{} for _ in range(R)]
    for lp, units in plan.buckets.items():
        owners = sharded.owner_of_list(
            np.array([u.glist for u in units], dtype=np.int64)
        )
        for u, r in zip(units, owners):
            rank_buckets[int(r)].setdefault(lp, []).append(u)
    return ShardedPlan(plan=plan, rank_buckets=rank_buckets)


def _coalesce_shapes(
    raw: Dict[int, List[WorkUnit]], max_shapes: int
) -> Dict[int, List[WorkUnit]]:
    """Enforce the compile-shape budget by rounding small pads up.

    Keeps the ``max_shapes`` largest padded lengths (the largest can never
    shrink) and folds every smaller bucket into the smallest survivor —
    correctness is unaffected because padding rows are masked invalid.
    """
    if max_shapes <= 0 or len(raw) <= max_shapes:
        return raw
    pads = sorted(raw)
    kept = pads[-max_shapes:]
    out: Dict[int, List[WorkUnit]] = {p: list(raw[p]) for p in kept}
    for p in pads[: -max_shapes]:
        out[kept[0]].extend(raw[p])
    return out
