"""Workload and dataset generators.

Two families, mirroring Section 6.1:

  * ``synthetic_bigann_style`` — BIGANN-style vectors with two random float
    attributes A, B and 20 range predicates of selectivity 2⁻ⁱ (10 per
    attribute); query log = Cartesian product of filters × query vectors.
    Used for the MSTuring/SIFT/YandexT2I-shaped experiments (Fig. 6, 7b, 7c).

  * ``kg_style`` — a KG-entity-shaped dataset with typed entities, set-valued
    type tags, NULL-heavy numeric/categorical properties, and *correlated*
    vectors (entities of a type cluster in embedding space — the correlation
    Section 2.3 calls out). The workload follows Table 1: ten templates
    (T1..T10) with skewed frequencies and selectivities from <0.005% to 60%,
    with IS NOT NULL / IN / Contains predicates over multiple attributes, and
    four temporal splits t0..t3 with mild drift (filter stability).
    Used for the RelatedQS/LP-shaped experiments (Tables 3–5, Fig. 4, 5, 7a).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .predicates import Between, Cmp, Contains, In, NotNull, make_filter
from .types import Column, METRIC_IP, METRIC_L2, VectorDatabase, Workload


# ---------------------------------------------------------------------------
# BIGANN-style synthetic (Section 6.1's public-dataset protocol)
# ---------------------------------------------------------------------------


def synthetic_bigann_style(
    n: int = 100_000,
    d: int = 64,
    n_query_vecs: int = 100,
    *,
    metric: str = METRIC_L2,
    levels: int = 10,
    seed: int = 0,
) -> Tuple[VectorDatabase, Workload, Dict[int, float]]:
    """Vectors + attrs A,B ~ U[0,1); 2·levels range predicates of sel. 2⁻ⁱ;

    query log = all filters × all query vectors (as in the paper). Returns
    (db, workload, selectivity per template index).
    """
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    a = rng.random(n).astype(np.float32)
    b = rng.random(n).astype(np.float32)
    db = VectorDatabase(
        vectors=vecs,
        columns={"A": Column.numeric("A", a), "B": Column.numeric("B", b)},
        metric=metric,
    )
    qvecs = rng.normal(size=(n_query_vecs, d)).astype(np.float32)
    templates = []
    sel = {}
    for attr in ("A", "B"):
        for i in range(levels):
            t = make_filter(Between(attr, 0.0, float(2.0**-i)))
            sel[len(templates)] = 2.0**-i
            templates.append(t)
    # Cartesian product: every query vector with every filter
    T = len(templates)
    vectors = np.repeat(qvecs, T, axis=0)
    template_of = np.tile(np.arange(T, dtype=np.int32), n_query_vecs)
    wl = Workload(vectors=vectors, templates=templates, template_of=template_of)
    return db, wl, sel


# ---------------------------------------------------------------------------
# KG-style industrial workload (RelatedQS / LP shaped)
# ---------------------------------------------------------------------------

# Table 1: (frequency at t0..t3, feasible-entity fraction) for T1..T10.
_TABLE1 = [
    # freq t0,  t1,   t2,   t3,   selectivity
    (0.15, 0.17, 0.17, 0.18, 0.00005),  # T1
    (0.26, 0.26, 0.26, 0.26, 0.001),  # T2
    (0.01, 0.01, 0.01, 0.01, 0.001),  # T3
    (0.24, 0.20, 0.20, 0.20, 0.005),  # T4
    (0.11, 0.12, 0.11, 0.12, 0.005),  # T5
    (0.02, 0.02, 0.02, 0.02, 0.01),  # T6
    (0.03, 0.03, 0.04, 0.03, 0.025),  # T7
    (0.15, 0.15, 0.15, 0.14, 0.30),  # T8
    (0.01, 0.01, 0.01, 0.01, 0.58),  # T9
    (0.04, 0.04, 0.04, 0.04, 0.60),  # T10
]


@dataclasses.dataclass
class KGDataset:
    db: VectorDatabase
    templates: List[tuple]
    selectivities: Dict[int, float]
    splits: List[Workload]  # t0..t3
    entity_type_of: np.ndarray


def kg_style(
    n: int = 100_000,
    d: int = 64,
    queries_per_split: int = 2_000,
    *,
    n_types: int = 12,
    seed: int = 0,
    metric: str = METRIC_IP,
) -> KGDataset:
    rng = np.random.default_rng(seed)

    # --- entities: type-clustered vectors (type ↔ vector correlation) -------
    type_of = rng.integers(0, n_types, size=n)
    type_centers = rng.normal(size=(n_types, d)).astype(np.float32) * 2.0
    vecs = (type_centers[type_of] + rng.normal(size=(n, d)).astype(np.float32)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True) + 1e-6

    # --- attributes ----------------------------------------------------------
    # "type": set-valued (primary type + optional secondary tags)
    membership = np.zeros((n, n_types), dtype=bool)
    membership[np.arange(n), type_of] = True
    extra = rng.random(n) < 0.2
    membership[np.nonzero(extra)[0], rng.integers(0, n_types, size=int(extra.sum()))] = True

    # numeric properties with type-dependent presence (NULL-heavy):
    def prop(presence_by_type: np.ndarray) -> Column:
        present = rng.random(n) < presence_by_type[type_of]
        vals = rng.random(n).astype(np.float32)
        return Column.numeric("x", vals, null_mask=~present)

    # "height": mostly only for type 0 ("Person"-like)
    pres = np.full(n_types, 0.02)
    pres[0] = 0.9
    height = prop(pres)
    height.name = "height"
    # "release_date": types 1,2 ("Song"/"Album"-like)
    pres = np.full(n_types, 0.05)
    pres[1] = pres[2] = 0.8
    release = prop(pres)
    release.name = "release_date"
    # "popularity": broadly present
    pres = np.full(n_types, 0.7)
    popularity = prop(pres)
    popularity.name = "popularity"
    # "country": categorical, broadly present
    country = Column.categorical(
        "country", rng.integers(0, 50, size=n).astype(np.int32), null_mask=rng.random(n) < 0.3
    )

    db = VectorDatabase(
        vectors=vecs,
        columns={
            "type": Column.setcat("type", membership),
            "height": height,
            "release_date": release,
            "popularity": popularity,
            "country": country,
        },
        metric=metric,
    )

    # --- templates tuned to Table-1 selectivities ----------------------------
    # Build candidate predicates, then calibrate each template to its target
    # selectivity by intersecting with a popularity range.
    def calibrated(base: tuple, target: float) -> tuple:
        from .predicates import evaluate_filter

        base_mask = evaluate_filter(base, db)
        frac = base_mask.mean()
        if frac <= target or frac == 0:
            return base
        # intersect with popularity < x to reach target
        pop = db.columns["popularity"]
        vals = pop.values[base_mask & ~pop.null_mask]
        if len(vals) == 0:
            return base
        keep = target / frac
        x = float(np.quantile(vals, min(1.0, keep)))
        return make_filter(*base, Cmp("popularity", "<", x), NotNull("popularity"))

    raw = [
        make_filter(Contains("type", 0), NotNull("height"), In("country", frozenset(range(2)))),  # T1
        make_filter(Contains("type", 0), NotNull("height")),  # T2
        make_filter(Contains("type", 1), NotNull("release_date"), In("country", frozenset(range(5)))),  # T3
        make_filter(Contains("type", 1), NotNull("release_date")),  # T4
        make_filter(Contains("type", 2), NotNull("release_date")),  # T5
        make_filter(Contains("type", 3), NotNull("popularity")),  # T6
        make_filter(In("country", frozenset(range(10))), NotNull("popularity")),  # T7
        make_filter(NotNull("popularity"), Cmp("popularity", ">=", 0.0)),  # T8
        make_filter(NotNull("country")),  # T9
        make_filter(NotNull("popularity")),  # T10
    ]
    templates = [calibrated(t, _TABLE1[i][4]) for i, t in enumerate(raw)]
    from .predicates import evaluate_filter

    sels = {i: float(evaluate_filter(t, db).mean()) for i, t in enumerate(templates)}

    # --- temporal splits (filter commonality + stability) --------------------
    splits = []
    for s in range(4):
        freqs = np.array([_TABLE1[i][s] for i in range(10)], dtype=np.float64)
        freqs /= freqs.sum()
        t_of = rng.choice(10, size=queries_per_split, p=freqs).astype(np.int32)
        # query vectors: embeddings of entities sampled near template-relevant
        # types (queries correlate with their filters, as in real KG logs)
        qv = np.empty((queries_per_split, d), dtype=np.float32)
        for i in range(queries_per_split):
            ti = t_of[i]
            if ti <= 5:
                base_type = [0, 0, 1, 1, 2, 3][ti]
            else:
                base_type = int(rng.integers(0, n_types))
            ent = rng.integers(0, n)
            # bias toward entities of the relevant type
            tries = 0
            while type_of[ent] != base_type and tries < 4:
                ent = rng.integers(0, n)
                tries += 1
            qv[i] = vecs[ent] + 0.05 * rng.normal(size=d).astype(np.float32)
        splits.append(Workload(vectors=qv, templates=list(templates), template_of=t_of))

    return KGDataset(
        db=db, templates=list(templates), selectivities=sels, splits=splits, entity_type_of=type_of
    )


# ---------------------------------------------------------------------------
# Workload reconstruction from observed traffic (the hot-swap tuner's input)
# ---------------------------------------------------------------------------


def reconstruct_workload(
    traffic: Sequence[Tuple[float, Hashable]],
    samples: Iterable[Tuple[np.ndarray, tuple, np.ndarray]] = (),
    *,
    fallback_vectors: np.ndarray,
    n_queries: int = 256,
    k: int = 10,
    seed: int = 0,
) -> Optional[Workload]:
    """A representative ``Workload`` rebuilt from drift-window observations.

    ``traffic`` is ``DriftMonitor.traffic_snapshot()``'s template window —
    ``(t, filter-tuple)`` pairs — and ``samples`` its recall reservoir
    (``(vector, filter, served_ids)``). Template *shares* come from traffic
    counts; query *vectors* per template come from the reservoir when it
    sampled that filter, else are drawn from ``fallback_vectors`` (the live
    DB rows — self-similarity is the standard stand-in when the real query
    vectors weren't retained). Returns None when the window is empty: no
    traffic means no evidence to re-partition on.

    Deterministic for a fixed (traffic, samples, seed): templates are
    ordered by their stringified filter, and every template observed in the
    window gets at least one query so rare-but-present filters keep their
    qd-tree say.
    """
    counts: Counter = Counter(key for _, key in traffic)
    if not counts:
        return None
    rng = np.random.default_rng(seed)
    templates = sorted(counts, key=str)
    total = sum(counts.values())
    pool: Dict[Hashable, List[np.ndarray]] = {}
    for vec, filt, _ in samples:
        pool.setdefault(filt, []).append(np.asarray(vec, dtype=np.float32))
    fallback = np.asarray(fallback_vectors, dtype=np.float32)
    vec_chunks: List[np.ndarray] = []
    t_of: List[int] = []
    for ti, filt in enumerate(templates):
        m = max(1, round(n_queries * counts[filt] / total))
        sampled = pool.get(filt, [])
        if sampled:
            picks = rng.integers(0, len(sampled), size=m)
            vec_chunks.append(np.stack([sampled[j] for j in picks]))
        else:
            vec_chunks.append(fallback[rng.integers(0, len(fallback), size=m)])
        t_of.extend([ti] * m)
    return Workload(
        vectors=np.concatenate(vec_chunks, axis=0),
        templates=list(templates),
        template_of=np.asarray(t_of, dtype=np.int32),
        k=int(k),
    )


def lp_style(
    n: int = 100_000,
    d: int = 64,
    n_queries: int = 2_000,
    *,
    n_types: int = 12,
    seed: int = 1,
) -> Tuple[VectorDatabase, Workload]:
    """Link-prediction-shaped workload: template = type-membership predicate

    only; no historical log (so HQI's qd-tree stage is skipped for it —
    batching-only, as in the paper)."""
    ds = kg_style(n, d, n_queries, n_types=n_types, seed=seed)
    rng = np.random.default_rng(seed + 1)
    t_of = rng.integers(0, n_types, size=n_queries).astype(np.int32)
    templates = [make_filter(Contains("type", t)) for t in range(n_types)]
    qv = ds.db.vectors[rng.integers(0, n, size=n_queries)] + 0.05 * rng.normal(
        size=(n_queries, d)
    ).astype(np.float32)
    wl = Workload(vectors=qv.astype(np.float32), templates=templates, template_of=t_of)
    return ds.db, wl
