"""Stage 2 of the execution engine: megabatched execution of a global plan.

``plan.py`` (stage 1) turns a whole workload into one ``ExecutionPlan`` whose
work units are bucketed by padded shape across every partition and template.
This module executes that plan:

  1. for each shape bucket, gather ALL its units' posting-list rows through
     the index-wide ``PackedArena`` (one gather serves every partition) and
     run them in a single ``kernels.ops.workunit_topk`` dispatch — the
     single-matmul-per-posting-list of Alg. 3 line 10, fused with the
     Section 4.2 bitmap pushdown, megabatched across the workload;
  2. scatter per-unit top-k into the candidate buffer — by default a flat
     segmented (CSR-style) [Σ seg_counts, k] buffer whose per-query segment
     widths come from ``ExecutionPlan.seg_counts``
     (``merge_layout="segmented"``); ``merge_layout="dense"`` keeps the
     legacy [m, n_slots, k] tensor padded to the widest query — then fold in
     any per-query scan results the adaptive executor produced host-side;
  3. reduce candidates to the final per-query top-k with ONE device-side
     reduction (``ops.segmented_merge_topk`` / ``ops.merge_topk``) — Alg. 3
     line 12 for the whole workload, replacing the per-(template ×
     partition) numpy merge loop. Both layouts are bit-identical: the
     segmented merge's stable sort reproduces ``lax.top_k``'s tie rule over
     the same slot-major candidate order (tests/test_engine_segmented.py).

Compressed execution (``PlanConfig.scan_mode="pq"``): the scan stage reads
the arena's uint8 PQ codes instead of raw f32 vectors — each bucket is one
``ops.workunit_pq_topk`` ADC dispatch producing ``refine_factor · k``
candidates per (query, posting list). Candidates from all buckets then merge
per query (one device merge), the survivors' f32 rows are gathered from the
arena ONCE, and a single ``workunit_topk`` dispatch re-ranks them exactly —
so dispatch cost stays O(#buckets) + 1 re-rank, never O(T×L), while scan HBM
traffic drops by d·4/M× (e.g. 32× at d=64, M=8). Bitmap pushdown composes
unchanged: the ADC stage applies the same ``valid`` mask, so re-rank
candidates already satisfy every predicate. The final merge still folds in
the adaptive executor's host-side (exact) candidates, which is sound because
re-ranked scores are exact too.

Dispatch cost is O(#buckets) ≤ ``PlanConfig.max_bucket_shapes`` instead of
O(T×L). In f32 mode every (query, posting-list) pair is evaluated exactly
once and each vector lives in exactly one list, so results are identical to
the per-query scan — tests assert equality of scores and candidate sets. In
pq mode that uniqueness also means the candidate union is duplicate-free.

Sharded execution (``execute_plan_sharded``): the same two stages across a
device mesh — each rank dispatches its shard's work units per bucket inside
one ``shard_map``, and the cross-rank merge is an all-gather of per-query
top-k candidates (``ops.sharded_merge_topk``, O(k·|model|) traffic). Results
are bit-identical to ``execute_plan``; ``core/distributed.py`` is the thin
mesh entry.

Memory: the segmented layout holds Σ seg_counts·k candidate rows instead of
m·n_slots·k, so queries routed to few partitions no longer pay for the
widest query's slots; on the sharded path each rank contributes only its
REAL segments to the pre-gather merge (Σ per-rank segments·k, vs the dense
[R, m, n_slots, k] stack). The pq path additionally keeps the workload's
ADC tables resident as one [U, M, 256] array and indexes them from inside
the kernel (``workunit_pq_topk_resident`` / the scalar-prefetch streamed
grid), never materializing the per-bucket [W, TQ, M, 256] expansion the
dense layout pays (``DispatchStats.lut_expand_bytes`` stays 0). Remaining
dense-stacking tax: sharded scan *operands* still ship [R, W, ...] per
bucket where W is the MAX per-rank unit count, so a shard-skewed unit
distribution transfers mostly-masked slices for the light ranks (ROADMAP).

``batch_search_ivf`` survives as the single-index entry point (used by the
baselines and benchmarks): it wraps the index in a one-partition arena,
builds a one-task plan, and executes it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..obs.profile import get_profiler
from ..obs.trace import fence, get_tracer
from .arena import PackedArena, ShardedArena
from .ivf import IVFIndex, ScanStats
from .plan import (
    EngineTask,
    ExecutionPlan,
    PlanConfig,
    ShardedPlan,
    WorkUnit,
    build_plan,
    _next_pow2,
)
from .pq import PQCodebook, adc_tables

# Extra per-query candidates merged alongside the plan's output (the adaptive
# executor's host-side scans): (qrows i64 [mq], scores f32 [mq, k], ids i64 [mq, k])
ExtraCandidates = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _account_candidates(stats: Optional[ScanStats], nbytes: int) -> None:
    """Record one candidate merge buffer allocation (scores + ids bytes):
    per-search peak in ScanStats, process-wide peak in DispatchStats — the
    figure the skewed-routing bench and the CI memory guard watch."""
    kops.dispatch_stats().record_candidate_bytes(nbytes)
    if stats is not None:
        stats.peak_candidate_bytes = max(stats.peak_candidate_bytes, int(nbytes))


def _account_lut(stats: Optional[ScanStats], nbytes: int, *, expanded: bool) -> None:
    """Record ADC LUT bytes materialized on device. ``expanded=True`` marks a
    per-unit [W, TQ, M, 256] expansion (the dense layout's gather operand) and
    also feeds ``DispatchStats.lut_expand_bytes`` — the counter the segmented
    path must leave untouched."""
    if expanded:
        kops.dispatch_stats().record_lut_expand(nbytes)
    if stats is not None:
        stats.lut_bytes += int(nbytes)


def _seg_offsets(
    plan_counts: np.ndarray, extra: Sequence[ExtraCandidates], m: int
) -> Tuple[np.ndarray, np.ndarray]:
    """CSR layout of the flat candidate buffer: (counts [m], offsets [m+1]).

    Query q owns flat rows offsets[q] .. offsets[q+1]-1 — its plan slots
    first (``plan_counts[q]`` of them, addressed as offsets[q] + slot), then
    one row per host-side extra. The per-query order matches the dense
    tensor's slot-major flattening, so the segmented merge selects the
    identical top-k (ties included)."""
    extra_counts = np.zeros(m, dtype=np.int64)
    for qrows, _, _ in extra:
        extra_counts[qrows] += 1
    counts = plan_counts + extra_counts
    offsets = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return counts, offsets


def _assemble_bucket(
    units: List[WorkUnit],
    lp: int,
    plan: ExecutionPlan,
    arena: PackedArena,
    w_pad: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Shared scan-stage assembly for one shape bucket.

    Returns (Vrows i64 [W, lp] packed rows to gather, valid bool [W, lp],
    qrow_of i64 [W, tq] workload query row per unit slot (-1 pad),
    slot_of i64 [W, tq] merge-tensor slot per unit slot). W is the unit count
    padded to a power of two so repeated workloads reuse a bounded set of
    compiled shapes (padding units are fully masked); the sharded executor
    passes ``w_pad`` so every rank assembles the same stacked width.
    """
    tq = plan.tq
    n_packed = arena.n
    W = _next_pow2(len(units), 1) if w_pad is None else w_pad
    Vrows = np.zeros((W, lp), dtype=np.int64)
    valid = np.zeros((W, lp), dtype=bool)
    qrow_of = np.full((W, tq), -1, dtype=np.int64)
    slot_of = np.zeros((W, tq), dtype=np.int64)
    for w, u in enumerate(units):
        s0 = int(arena.list_start[u.glist])
        llen = int(arena.list_len[u.glist])
        rows = np.minimum(np.arange(lp) + s0, n_packed - 1)
        Vrows[w] = rows
        v_ok = np.arange(lp) < llen
        task = plan.tasks[u.task]
        if task.packed_bitmap is not None:
            pb = task.packed_bitmap
            local = np.minimum(rows - int(arena.part_row[task.part]), len(pb) - 1)
            v_ok = v_ok & pb[local]
        valid[w] = v_ok
        nq = len(u.qrows)
        qrow_of[w, :nq] = u.qrows
        slot_of[w, :nq] = u.slots
    return Vrows, valid, qrow_of, slot_of


def execute_plan(
    plan: ExecutionPlan,
    arena: Optional[PackedArena],  # None allowed iff the plan has no buckets
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    cfg: Optional[PlanConfig] = None,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (scores f32 [m, k] best-first, arena gids i64 [m, k]; -1 pad)."""
    cfg = PlanConfig() if cfg is None else cfg
    if cfg.scan_mode == "pq" and plan.buckets:
        if arena.codes is None or arena.pq is None:
            raise ValueError(
                "scan_mode='pq' needs a PQ-encoded arena: build the HQIIndex "
                "with HQIConfig(scan_mode='pq'), or pass pq= to "
                "batch_search_ivf; baseline indexes support scan_mode='f32' only"
            )
        return _execute_plan_pq(plan, arena, q_vecs, cfg=cfg, extra=extra, stats=stats)
    if cfg.scan_mode not in ("f32", "pq"):
        raise ValueError(f"unknown scan_mode {cfg.scan_mode!r}")
    if cfg.merge_layout not in ("segmented", "dense"):
        raise ValueError(f"unknown merge_layout {cfg.merge_layout!r}")
    m, k, tq = plan.m, plan.k, plan.tq
    # extras get per-query-dense slot columns after the plan's own slots
    n_slots = plan.n_slots + _extra_slot_width(extra, m)
    if m == 0 or n_slots == 0:
        return (
            np.full((m, k), -np.inf, np.float32),
            np.full((m, k), -1, np.int64),
        )
    if cfg.merge_layout == "segmented":
        return _execute_plan_f32_segmented(
            plan, arena, q_vecs, cfg=cfg, extra=extra, stats=stats
        )

    out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
    _account_candidates(stats, out_scores.nbytes + out_idx.nbytes)
    d = q_vecs.shape[1]

    for kk, qr, sl, s_w, gidx_w in _iter_f32_buckets(plan, arena, q_vecs, cfg, stats):
        out_scores[qr, sl, :kk] = s_w
        out_idx[qr, sl, :kk] = gidx_w

    return _fold_extras_and_merge(out_scores, out_idx, extra, plan.n_slots, k)


def _iter_f32_buckets(plan, arena, q_vecs, cfg, stats):
    """Run the f32 scan stage bucket by bucket (one ``workunit_topk`` dispatch
    each), yielding (kk, qrows, slots, scores [n, kk], gids [n, kk]) for the
    real unit slots — the scatter destination is the only thing the dense and
    segmented layouts disagree on, so the scan math lives here once."""
    m, k, tq = plan.m, plan.k, plan.tq
    d = q_vecs.shape[1]
    prof = get_profiler()
    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        Vrows, valid, qrow_of, slot_of = _assemble_bucket(units, lp, plan, arena)
        W = Vrows.shape[0]
        Q = np.zeros((W, tq, d), dtype=np.float32)
        wmask = qrow_of >= 0  # [W, tq]
        Q[wmask] = q_vecs[qrow_of[wmask]]
        V = arena.packed[Vrows]  # [W, lp, d] — one gather across all partitions
        if stats is not None:
            # real work units only (pow2 pad excluded), so the figure is
            # comparable across configurations — the sharded executor counts
            # the same way per rank
            stats.bytes_scanned += len(units) * lp * d * 4
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span("dispatch.scan", mode="f32", lp=lp, units=len(units)):
            s, i_loc = kops.workunit_topk(
                jnp.asarray(Q),
                jnp.asarray(V),
                jnp.asarray(valid),
                min(k, lp),
                metric=arena.metric,
                use_pallas=cfg.use_pallas,
                interpret=cfg.interpret,
            )
            s, i_loc = fence(s, i_loc)  # device time is real iff tracing is on
        if prof.enabled:
            # real distance work: 2·d MACs per (query, live row) pair within
            # each unit; padded work covers the full [W, tq, lp] bucket
            nq_u = wmask.sum(axis=1)
            rows_u = valid.sum(axis=1)
            prof.record_dispatch(
                "scan", "f32", lp, t0,
                nbytes=Q.nbytes + V.nbytes + valid.nbytes
                + W * tq * min(k, lp) * 12,
                flops=2.0 * d * float((nq_u * rows_u).sum()),
                flops_padded=2.0 * d * W * tq * lp,
                units=len(units), units_padded=W,
                rows=int(rows_u.sum()), rows_padded=W * lp,
            )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # index within the unit's lp rows (-1 = none)
        kk = s.shape[-1]
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        gidx = arena.gid[packed_rows]
        gidx = np.where(i_loc < 0, -1, gidx)
        yield kk, qrow_of[wmask], slot_of[wmask], s[wmask], gidx[wmask]


def _plan_seg_counts(plan: ExecutionPlan) -> np.ndarray:
    """Per-query plan slot counts, tolerating plans built before the field
    existed (deserialized or hand-constructed): fall back to the dense
    assumption that every query owns ``n_slots`` slots."""
    if len(plan.seg_counts) == plan.m:
        return plan.seg_counts
    return np.full(plan.m, plan.n_slots, dtype=np.int64)


def _execute_plan_f32_segmented(
    plan: ExecutionPlan,
    arena: Optional[PackedArena],
    q_vecs: np.ndarray,
    *,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates],
    stats: Optional[ScanStats],
) -> Tuple[np.ndarray, np.ndarray]:
    """Segmented (CSR) counterpart of the dense f32 path.

    Per-unit top-ks scatter into ONE flat [C_pad, k] buffer at
    offsets[q] + slot — query q's segment holds exactly its own plan slots
    plus its host-side extras, so peak merge memory is Σ seg_counts·k
    instead of m·n_slots·k. One ``segmented_merge_topk`` dispatch reduces
    every ragged segment; within each segment candidates keep the dense
    layout's slot-major order, so results are bit-identical (parity suite).
    """
    m, k = plan.m, plan.k
    plan_counts = _plan_seg_counts(plan)
    counts, offsets = _seg_offsets(plan_counts, extra, m)
    C_total = int(offsets[-1])
    C_pad = _next_pow2(C_total, 1)
    flat_s = np.full((C_pad, k), -np.inf, dtype=np.float32)
    flat_i = np.full((C_pad, k), -1, dtype=np.int64)
    seg_of = np.full(C_pad, m, dtype=np.int32)  # pad rows -> dropped segment
    seg_of[:C_total] = np.repeat(np.arange(m, dtype=np.int32), counts)
    _account_candidates(stats, flat_s.nbytes + flat_i.nbytes)

    for kk, qr, sl, s_w, gidx_w in _iter_f32_buckets(plan, arena, q_vecs, cfg, stats):
        rows = offsets[qr] + sl
        flat_s[rows, :kk] = s_w
        flat_i[rows, :kk] = gidx_w

    # extras take the rows after each query's plan slots (same relative order
    # as the dense layout's extra columns)
    next_extra = plan_counts.copy()
    for qrows, es, ei in extra:
        kk = min(k, es.shape[1])
        rows = offsets[qrows] + next_extra[qrows]
        next_extra[qrows] += 1
        flat_s[rows, :kk] = es[:, :kk]
        flat_i[rows, :kk] = ei[:, :kk]

    prof = get_profiler()
    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("merge.segmented", m=m, candidates=C_total):
        top_s, top_i = kops.segmented_merge_topk(
            jnp.asarray(flat_s), jnp.asarray(flat_i), jnp.asarray(seg_of), m, k
        )
        top_s, top_i = fence(top_s, top_i)
    if prof.enabled:
        prof.record_dispatch(
            "merge", "segmented", C_pad, t0,
            nbytes=flat_s.nbytes + flat_i.nbytes + seg_of.nbytes + m * k * 12,
            flops=0.0, flops_padded=0.0,
            units=m, units_padded=m,
            rows=C_total, rows_padded=C_pad,
        )
    return np.asarray(top_s, dtype=np.float32), np.asarray(top_i, dtype=np.int64)


def _extra_slot_width(extra: Sequence[ExtraCandidates], m: int) -> int:
    """Max per-query count of host-side extra candidate columns."""
    extra_slots = np.zeros(m, dtype=np.int64)
    for qrows, _, _ in extra:
        extra_slots[qrows] += 1
    return int(extra_slots.max()) if m else 0


def _fold_extras_and_merge(
    out_scores: np.ndarray,  # f32 [m, n_slots, k] — base candidates filled in
    out_idx: np.ndarray,  # i64 [m, n_slots, k]
    extra: Sequence[ExtraCandidates],
    base_slots: int,  # extras occupy slot columns base_slots, base_slots+1, ...
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fold the adaptive executor's host-side candidates in, then final-merge.

    Shared tail of both scan modes, so extras handling can never diverge
    between the f32 and pq paths.
    """
    m = out_scores.shape[0]
    next_extra = np.full(m, base_slots, dtype=np.int64)
    for qrows, es, ei in extra:
        kk = min(k, es.shape[1])
        slot = next_extra[qrows]
        next_extra[qrows] += 1
        out_scores[qrows, slot, :kk] = es[:, :kk]
        out_idx[qrows, slot, :kk] = ei[:, :kk]
    top_s, top_i = _padded_merge(out_scores.reshape(m, -1), out_idx.reshape(m, -1), k)
    return np.asarray(top_s, dtype=np.float32), np.asarray(top_i, dtype=np.int64)


def _padded_merge(
    flat_s: np.ndarray, flat_i: np.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """merge_topk with the candidate width padded to a power of two (so
    repeated workloads reuse a bounded set of compiled merge shapes)."""
    real_width = flat_s.shape[1]
    width = _next_pow2(real_width, k)
    if width > real_width:
        padc = width - real_width
        flat_s = np.pad(flat_s, ((0, 0), (0, padc)), constant_values=-np.inf)
        flat_i = np.pad(flat_i, ((0, 0), (0, padc)), constant_values=-1)
    mq = flat_s.shape[0]
    prof = get_profiler()
    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("merge.final", m=mq, width=width):
        s, i = kops.merge_topk(jnp.asarray(flat_s), jnp.asarray(flat_i), k)
        s, i = fence(s, i)
    if prof.enabled:
        prof.record_dispatch(
            "merge", "final", width, t0,
            nbytes=flat_s.nbytes + flat_i.nbytes + mq * k * 12,
            flops=0.0, flops_padded=0.0,
            units=mq, units_padded=mq,
            rows=mq * real_width, rows_padded=mq * width,
        )
    return s, i


def _execute_plan_pq(
    plan: ExecutionPlan,
    arena: PackedArena,
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Compressed two-stage execution: ADC scan over codes, then exact re-rank.

    Stage A — per shape bucket, ONE ``workunit_pq_topk`` dispatch scans uint8
    code tiles with each unit's VMEM-resident per-query LUTs, keeping
    k′ = refine_factor · k ADC candidates per (query, posting list).
    Stage B — candidates from all buckets merge to the per-query top-k′ (one
    device merge over ADC scores), their f32 rows are gathered from the arena
    once, and ONE ``workunit_topk`` dispatch re-scores them exactly. The
    final merge then folds in the adaptive executor's host-side candidates,
    exactly like the f32 path.
    """
    m, k = plan.m, plan.k
    kprime = max(k, int(cfg.refine_factor) * k)

    # ADC tables only for queries the plan actually scans (the adaptive
    # executor may have routed most of the workload to host-side extras),
    # shipped to the device ONCE as a resident [U, M, 256] array. The
    # segmented layout indexes it directly from the dispatch (per-unit-slot
    # LUT rows via scalar-prefetch on the Pallas path) so no per-bucket
    # [W, tq, M, 256] operand ever materializes; the dense layout keeps the
    # device-side gather expansion as the comparison baseline, which
    # ``DispatchStats.lut_expand_bytes`` meters.
    used = np.unique(
        np.concatenate(
            [u.qrows for units in plan.buckets.values() for u in units]
        )
    )
    lut_pos = np.zeros(m, dtype=np.int64)
    lut_pos[used] = np.arange(len(used))
    luts_dev = jnp.asarray(adc_tables(arena.pq, q_vecs[used]))  # [U, M, 256]
    _account_lut(stats, int(luts_dev.nbytes), expanded=False)

    if cfg.merge_layout == "segmented":
        rows = _pq_stage_a_segmented(
            plan, arena, luts_dev, lut_pos, kprime, cfg=cfg, stats=stats
        )
    else:
        rows = _pq_stage_a_dense(
            plan, arena, luts_dev, lut_pos, kprime, cfg=cfg, stats=stats
        )
    return _pq_rerank_and_fold(
        arena, q_vecs, rows, k=k, kprime=kprime, cfg=cfg, extra=extra, stats=stats
    )


def _pq_stage_a_dense(
    plan: ExecutionPlan,
    arena: PackedArena,
    luts_dev: jnp.ndarray,  # f32 [U, M, 256]
    lut_pos: np.ndarray,  # i64 [m] — LUT row per workload query
    kprime: int,
    *,
    cfg: PlanConfig,
    stats: Optional[ScanStats],
) -> np.ndarray:
    """Dense ADC stage A: [m, n_slots, k'] scatter + rectangular merge.
    Returns the surviving global packed rows i64 [m, k'] (-1 pad)."""
    m = plan.m
    cand_s = np.full((m, plan.n_slots, kprime), -np.inf, dtype=np.float32)
    cand_rows = np.full((m, plan.n_slots, kprime), -1, dtype=np.int64)
    _account_candidates(stats, cand_s.nbytes + cand_rows.nbytes)
    prof = get_profiler()

    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        Vrows, valid, qrow_of, slot_of = _assemble_bucket(units, lp, plan, arena)
        W = Vrows.shape[0]
        wmask = qrow_of >= 0
        # padding slots map to LUT row 0; their outputs are dropped via wmask
        luts = jnp.take(
            luts_dev, jnp.asarray(lut_pos[np.maximum(qrow_of, 0)]), axis=0
        )  # [W, tq, M, 256], gathered on device
        _account_lut(stats, int(luts.nbytes), expanded=True)
        codes = arena.codes[Vrows]  # [W, lp, M] uint8 — the compressed gather
        if stats is not None:
            stats.bytes_scanned += len(units) * lp * arena.codes.shape[1]
        kk = min(kprime, lp)
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span("dispatch.scan", mode="pq", lp=lp, units=len(units)):
            s, i_loc = kops.workunit_pq_topk(
                jnp.asarray(luts),
                jnp.asarray(codes),
                jnp.asarray(valid),
                kk,
                use_pallas=cfg.use_pallas,
                interpret=cfg.interpret,
            )
            s, i_loc = fence(s, i_loc)
        if prof.enabled:
            # one-hot MXU contraction: 2·M·256 MACs per (query, live row)
            M = codes.shape[2]
            nq_u = wmask.sum(axis=1)
            rows_u = valid.sum(axis=1)
            prof.record_dispatch(
                "scan", "pq", lp, t0,
                nbytes=int(luts.nbytes) + codes.nbytes + valid.nbytes
                + W * plan.tq * kk * 12,
                flops=2.0 * M * 256 * float((nq_u * rows_u).sum()),
                flops_padded=2.0 * M * 256 * W * plan.tq * lp,
                units=len(units), units_padded=W,
                rows=int(rows_u.sum()), rows_padded=W * lp,
            )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # [W, tq, kk] index into the unit's lp rows
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        packed_rows = np.where(i_loc < 0, -1, packed_rows)
        qr = qrow_of[wmask]
        sl = slot_of[wmask]
        cand_s[qr, sl, :kk] = s[wmask]
        cand_rows[qr, sl, :kk] = packed_rows[wmask]

    # per-query top-k' ADC candidates across every bucket and probe slot
    _, top_rows = _padded_merge(
        cand_s.reshape(m, -1), cand_rows.reshape(m, -1), kprime
    )
    return np.asarray(top_rows, dtype=np.int64)  # [m, k'] packed rows (-1 pad)


def _pq_stage_a_segmented(
    plan: ExecutionPlan,
    arena: PackedArena,
    luts_dev: jnp.ndarray,  # f32 [U, M, 256]
    lut_pos: np.ndarray,  # i64 [m]
    kprime: int,
    *,
    cfg: PlanConfig,
    stats: Optional[ScanStats],
) -> np.ndarray:
    """Segmented ADC stage A: flat [Σ seg_counts, k'] scatter + ragged merge.

    Each bucket dispatches ``workunit_pq_topk_resident`` — the kernel indexes
    the resident LUT table by per-slot row, so the dense path's per-bucket
    [W, tq, M, 256] expansion never materializes (lut_expand_bytes stays 0).
    Returns the surviving global packed rows i64 [m, k'] (-1 pad).
    """
    m = plan.m
    counts = _plan_seg_counts(plan)  # stage A has no extras; they fold post re-rank
    offsets = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    C_total = int(offsets[-1])
    C_pad = _next_pow2(C_total, 1)
    flat_s = np.full((C_pad, kprime), -np.inf, dtype=np.float32)
    flat_rows = np.full((C_pad, kprime), -1, dtype=np.int64)
    seg_of = np.full(C_pad, m, dtype=np.int32)
    seg_of[:C_total] = np.repeat(np.arange(m, dtype=np.int32), counts)
    _account_candidates(stats, flat_s.nbytes + flat_rows.nbytes)
    prof = get_profiler()

    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        Vrows, valid, qrow_of, slot_of = _assemble_bucket(units, lp, plan, arena)
        wmask = qrow_of >= 0
        lut_idx = lut_pos[np.maximum(qrow_of, 0)]  # [W, tq]; pads -> LUT row 0
        codes = arena.codes[Vrows]  # [W, lp, M] uint8
        if stats is not None:
            stats.bytes_scanned += len(units) * lp * arena.codes.shape[1]
        kk = min(kprime, lp)
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span("dispatch.scan", mode="pq-res", lp=lp, units=len(units)):
            s, i_loc = kops.workunit_pq_topk_resident(
                luts_dev,
                jnp.asarray(lut_idx),
                jnp.asarray(codes),
                jnp.asarray(valid),
                kk,
                use_pallas=cfg.use_pallas,
                interpret=cfg.interpret,
            )
            s, i_loc = fence(s, i_loc)
        if prof.enabled:
            # the resident path streams one [M, 256] LUT row per LIVE query
            # slot instead of expanding [W, tq, M, 256]
            M = codes.shape[2]
            W = Vrows.shape[0]
            nq_u = wmask.sum(axis=1)
            rows_u = valid.sum(axis=1)
            prof.record_dispatch(
                "scan", "pq-res", lp, t0,
                nbytes=codes.nbytes + valid.nbytes
                + int(nq_u.sum()) * M * 256 * 4 + W * plan.tq * kk * 12,
                flops=2.0 * M * 256 * float((nq_u * rows_u).sum()),
                flops_padded=2.0 * M * 256 * W * plan.tq * lp,
                units=len(units), units_padded=W,
                rows=int(rows_u.sum()), rows_padded=W * lp,
            )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        packed_rows = np.where(i_loc < 0, -1, packed_rows)
        qr = qrow_of[wmask]
        rows_f = offsets[qr] + slot_of[wmask]
        flat_s[rows_f, :kk] = s[wmask]
        flat_rows[rows_f, :kk] = packed_rows[wmask]

    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("merge.segmented", m=m, candidates=C_total):
        _, top_rows = kops.segmented_merge_topk(
            jnp.asarray(flat_s), jnp.asarray(flat_rows), jnp.asarray(seg_of), m, kprime
        )
        top_rows = fence(top_rows)
    if prof.enabled:
        prof.record_dispatch(
            "merge", "segmented", C_pad, t0,
            nbytes=flat_s.nbytes + flat_rows.nbytes + seg_of.nbytes
            + m * kprime * 12,
            flops=0.0, flops_padded=0.0,
            units=m, units_padded=m,
            rows=C_total, rows_padded=C_pad,
        )
    return np.asarray(top_rows, dtype=np.int64)


def _pq_rerank_and_fold(
    arena: PackedArena,
    q_vecs: np.ndarray,
    rows: np.ndarray,  # i64 [m, k'] surviving global packed rows (-1 pad)
    *,
    k: int,
    kprime: int,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates],
    stats: Optional[ScanStats],
) -> Tuple[np.ndarray, np.ndarray]:
    """Stage B shared by both layouts: exact re-rank + extras fold."""
    m, d = q_vecs.shape

    # exact re-rank: one gather of the surviving f32 rows + one dispatch.
    # Units are per-query (TQ=1) so each query re-scores only ITS candidates;
    # m pads to a power of two for compile-shape reuse.
    mp = _next_pow2(m, 1)
    Qr = np.zeros((mp, 1, d), dtype=np.float32)
    Qr[:m, 0] = q_vecs
    Vr = np.zeros((mp, kprime, d), dtype=np.float32)
    Vr[:m] = arena.packed[np.maximum(rows, 0)]
    valid_r = np.zeros((mp, kprime), dtype=bool)
    valid_r[:m] = rows >= 0
    if stats is not None:
        # real surviving candidates only (matches the sharded re-rank)
        stats.bytes_scanned += int((rows >= 0).sum()) * d * 4
    prof = get_profiler()
    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("rerank.exact", m=m, kprime=kprime):
        s, i_loc = kops.workunit_topk(
            jnp.asarray(Qr),
            jnp.asarray(Vr),
            jnp.asarray(valid_r),
            min(k, kprime),
            metric=arena.metric,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        s, i_loc = fence(s, i_loc)
    if prof.enabled:
        n_real = int((rows >= 0).sum())
        prof.record_dispatch(
            "rerank", "f32", kprime, t0,
            nbytes=Qr.nbytes + Vr.nbytes + valid_r.nbytes
            + mp * min(k, kprime) * 12,
            flops=2.0 * d * n_real,
            flops_padded=2.0 * d * mp * kprime,
            units=m, units_padded=mp,
            rows=n_real, rows_padded=mp * kprime,
        )
    s = np.asarray(s)[:m, 0]  # [m, kk] exact scores
    i_loc = np.asarray(i_loc)[:m, 0]  # [m, kk] index into the k' candidates
    kk = s.shape[-1]
    packed_rows = np.take_along_axis(rows, np.maximum(i_loc, 0).astype(np.int64), axis=1)
    gidx = np.where(i_loc < 0, -1, arena.gid[np.maximum(packed_rows, 0)])
    gidx = np.where(packed_rows < 0, -1, gidx)

    # final merge: re-ranked (exact) plan results in slot 0 + host-side exact
    # extras in the columns after it — the same tail as the f32 path
    n_slots = 1 + _extra_slot_width(extra, m)
    out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
    _account_candidates(stats, out_scores.nbytes + out_idx.nbytes)
    out_scores[:, 0, :kk] = np.where(gidx >= 0, s, -np.inf)
    out_idx[:, 0, :kk] = gidx
    return _fold_extras_and_merge(out_scores, out_idx, extra, 1, k)


# ----------------------------------------------------------------- sharded

@dataclasses.dataclass
class ShardStats:
    """Per-rank accounting of one sharded execution (the bench/test probe).

    ``per_rank_bytes`` counts arena bytes each rank's scan stages gathered
    for its REAL work units (stacking pad excluded) — the quantity that must
    shrink ~1/|model| per rank versus a single device. ``gathered_per_query``
    is the total candidate columns the all-gather merges moved per query:
    O(k · |model|) by construction, independent of DB size, which the parity
    suite asserts as the engine's entire cross-rank traffic.
    """

    n_shards: int
    per_rank_bytes: np.ndarray  # i64 [R] — arena bytes scanned by rank r
    per_rank_units: np.ndarray  # i64 [R] — real work units executed by rank r
    per_rank_dispatches: np.ndarray  # i64 [R] — stages rank r had live work in
    gathered_per_query: int = 0  # candidate columns all-gathered per query

    @staticmethod
    def zeros(n_shards: int) -> "ShardStats":
        return ShardStats(
            n_shards=int(n_shards),
            per_rank_bytes=np.zeros(n_shards, dtype=np.int64),
            per_rank_units=np.zeros(n_shards, dtype=np.int64),
            per_rank_dispatches=np.zeros(n_shards, dtype=np.int64),
        )


def execute_plan_sharded(
    splan: ShardedPlan,
    sharded: ShardedArena,
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    mesh,
    axis: str = "model",
    cfg: Optional[PlanConfig] = None,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
    shard_stats: Optional[ShardStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 2 across a device mesh — bit-identical to ``execute_plan``.

    Per shared shape bucket, every rank's work units stack along the mesh
    axis and run as ONE ``sharded_workunit_topk`` (or ``_pq_topk``) dispatch:
    rank r gathers rows/codes only from ITS arena shard, so per-rank scan
    traffic is its shard's share of the workload. Candidates then reduce in
    two hops: a rank-local top-k over each rank's own candidate tensor,
    followed by the all-gather merge (``sharded_merge_topk``) whose traffic
    is k·|model| (score, id) pairs per query — never distance rows, never
    O(n). Extras (the adaptive executor's host-side exact scans) fold into
    the final merge exactly like the single-device paths.

    Parity argument (what tests/test_engine_sharded.py asserts): every
    (query, posting-list) pair lives on exactly one rank and is evaluated
    with the same per-unit kernel math as the single-device engine, so the
    union of per-rank candidates equals the single-device candidate set and
    the two-hop top-k selects the identical result. Caveat: candidates with
    EXACTLY equal scores straddling the k (or pq k′) boundary may resolve in
    a different order than the single-device flat merge (top_k breaks ties
    by position, and the two layouts order candidates differently) — both
    answers are correct top-ks; on continuous data exact ties are duplicate
    vectors.
    """
    cfg = PlanConfig() if cfg is None else cfg
    if cfg.scan_mode not in ("f32", "pq"):
        raise ValueError(f"unknown scan_mode {cfg.scan_mode!r}")
    sstats = ShardStats.zeros(sharded.n_shards) if shard_stats is None else shard_stats
    sstats.per_rank_units += splan.per_rank_units
    m, k = splan.plan.m, splan.plan.k
    if m == 0 or splan.n_units == 0:
        n_slots = _extra_slot_width(extra, m)
        if m == 0 or n_slots == 0:
            return (
                np.full((m, k), -np.inf, np.float32),
                np.full((m, k), -1, np.int64),
            )
        out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
        out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
        return _fold_extras_and_merge(out_scores, out_idx, extra, 0, k)
    if cfg.scan_mode == "pq":
        if sharded.base.codes is None or sharded.base.pq is None:
            raise ValueError(
                "scan_mode='pq' needs a PQ-encoded arena: build the HQIIndex "
                "with HQIConfig(scan_mode='pq'), or pass pq= to "
                "batch_search_ivf; baseline indexes support scan_mode='f32' only"
            )
        return _execute_sharded_pq(
            splan, sharded, q_vecs, mesh=mesh, axis=axis, cfg=cfg,
            extra=extra, stats=stats, sstats=sstats,
        )
    return _execute_sharded_f32(
        splan, sharded, q_vecs, mesh=mesh, axis=axis, cfg=cfg,
        extra=extra, stats=stats, sstats=sstats,
    )


def _assemble_bucket_stacked(
    splan: ShardedPlan,
    sharded: ShardedArena,
    lp: int,
    q_vecs: np.ndarray,
    with_q: bool = True,
) -> Tuple[np.ndarray, ...]:
    """Stack every rank's bucket assembly along the mesh axis (host side).

    Returns (unit_lists, Q [R,W,tq,d], valid [R,W,lp], qrow_of, slot_of,
    Vrows [R,W,lp], wmask). Assembly runs against the BASE arena — a rank's
    units reference only posting lists it owns, so slice r of ``Vrows``
    addresses rank r's rows (up to fully-masked clamp padding). Ranks
    without units in this bucket contribute fully-masked zero slices; W is
    the max rank unit count padded pow2 so all ranks share one dispatch
    shape. ``with_q=False`` (the ADC path, which scans with LUTs instead of
    query vectors) skips the query-tile allocation and gather and returns
    ``Q=None``.
    """
    R = sharded.n_shards
    tq, d = splan.plan.tq, q_vecs.shape[1]
    unit_lists = [splan.rank_buckets[r].get(lp, []) for r in range(R)]
    W = _next_pow2(max(len(u) for u in unit_lists), 1)
    valid = np.zeros((R, W, lp), dtype=bool)
    qrow_of = np.full((R, W, tq), -1, dtype=np.int64)
    slot_of = np.zeros((R, W, tq), dtype=np.int64)
    Vrows = np.zeros((R, W, lp), dtype=np.int64)
    for r in range(R):
        if not unit_lists[r]:
            continue
        vr, va, qr, sl = _assemble_bucket(
            unit_lists[r], lp, splan.plan, sharded.base, w_pad=W
        )
        Vrows[r], valid[r], qrow_of[r], slot_of[r] = vr, va, qr, sl
    wmask = qrow_of >= 0
    Q = None
    if with_q:
        Q = np.zeros((R, W, tq, d), dtype=np.float32)
        Q[wmask] = q_vecs[qrow_of[wmask]]
    return unit_lists, Q, valid, qrow_of, slot_of, Vrows, wmask


def _merge_with_extras(
    ms: np.ndarray,  # f32 [m, k] — the sharded gather merge's final top-k
    mi: np.ndarray,  # i64 [m, k]
    extra: Sequence[ExtraCandidates],
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Shared tail of both sharded scan modes: fold the adaptive executor's
    host-side exact candidates (if any) into the merged device result —
    slot 0 holds the sharded top-k, extras take the columns after it."""
    if not extra:
        return ms, mi  # the gather merge already IS the final per-query top-k
    m = ms.shape[0]
    out_slots = 1 + _extra_slot_width(extra, m)
    out_scores = np.full((m, out_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, out_slots, k), -1, dtype=np.int64)
    out_scores[:, 0] = ms
    out_idx[:, 0] = mi
    return _fold_extras_and_merge(out_scores, out_idx, extra, 1, k)


def _gather_merge(
    mesh,
    axis: str,
    cand_s: np.ndarray,  # f32 [R, m, n_slots, kk] per-rank candidate tensors
    cand_i: np.ndarray,  # i64 [R, m, n_slots, kk]
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Two-hop reduction: rank-local top-k, then the k·|model| gather merge.
    Candidate width pads pow2 (≥ k) so repeated workloads reuse compiled
    merge shapes, like the single-device ``_padded_merge``."""
    R, m = cand_s.shape[:2]
    flat_s = cand_s.reshape(R, m, -1)
    flat_i = cand_i.reshape(R, m, -1)
    real_width = flat_s.shape[2]
    width = _next_pow2(real_width, k)
    if width > real_width:
        padc = width - real_width
        flat_s = np.pad(flat_s, ((0, 0), (0, 0), (0, padc)), constant_values=-np.inf)
        flat_i = np.pad(flat_i, ((0, 0), (0, 0), (0, padc)), constant_values=-1)
    prof = get_profiler()
    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("merge.gather", ranks=R, m=m, width=width):
        ms, mi = kops.sharded_merge_topk(
            mesh, axis, jnp.asarray(flat_s), jnp.asarray(flat_i), k
        )
        ms, mi = fence(ms, mi)
    if prof.enabled:
        prof.record_dispatch(
            "gather", "sharded", width, t0,
            nbytes=flat_s.nbytes + flat_i.nbytes + m * k * 12,
            flops=0.0, flops_padded=0.0,
            units=R * m, units_padded=R * m,
            rows=R * m * real_width, rows_padded=R * m * width,
        )
    return np.asarray(ms, dtype=np.float32), np.asarray(mi, dtype=np.int64)


def _rank_segments(
    splan: ShardedPlan, R: int, m: int
) -> Tuple[int, List[np.ndarray], np.ndarray, np.ndarray]:
    """Per-rank CSR layout for the sharded segmented merge.

    Every (query, slot) pair lives in exactly one work unit, hence on exactly
    one rank — so each rank's candidate rows are the sorted set of its own
    ``q · S + slot`` keys (S spans the slot range). Returns
    (S, rank_keys [R sorted i64 arrays], base [R+1] flat-row offsets,
    seg_of [Σ|keys|] i32): rank r's candidates occupy flat rows
    base[r]..base[r+1]-1 with segment id r·m + q — ascending, because rows
    sort by (rank, query, slot). One segmented merge over R·m segments then
    equals every rank's local [m, k] top-k, with the light ranks paying for
    exactly their own segments instead of a dense [R, m, n_slots, k] stack.
    """
    S = max(splan.plan.n_slots, 1)
    rank_keys: List[np.ndarray] = []
    base = np.zeros(R + 1, dtype=np.int64)
    segs: List[np.ndarray] = []
    for r in range(R):
        ks = [
            u.qrows * S + u.slots
            for units in splan.rank_buckets[r].values()
            for u in units
        ]
        kr = np.sort(np.concatenate(ks)) if ks else np.zeros(0, dtype=np.int64)
        rank_keys.append(kr)
        base[r + 1] = base[r] + len(kr)
        segs.append(r * m + (kr // S).astype(np.int32))
    seg_of = (
        np.concatenate(segs).astype(np.int32)
        if int(base[-1])
        else np.zeros(0, dtype=np.int32)
    )
    return S, rank_keys, base, seg_of


def _execute_sharded_f32(
    splan: ShardedPlan,
    sharded: ShardedArena,
    q_vecs: np.ndarray,
    *,
    mesh,
    axis: str,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates],
    stats: Optional[ScanStats],
    sstats: ShardStats,
) -> Tuple[np.ndarray, np.ndarray]:
    R = sharded.n_shards
    m, k = splan.plan.m, splan.plan.k
    d = q_vecs.shape[1]
    arena = sharded.base
    n_slots = splan.plan.n_slots
    segmented = cfg.merge_layout == "segmented"
    if segmented:
        S, rank_keys, base, seg_pref = _rank_segments(splan, R, m)
        C_pad = _next_pow2(int(base[-1]), 1)
        flat_s = np.full((C_pad, k), -np.inf, dtype=np.float32)
        flat_i = np.full((C_pad, k), -1, dtype=np.int64)
        seg_of = np.full(C_pad, R * m, dtype=np.int32)
        seg_of[: int(base[-1])] = seg_pref
        _account_candidates(stats, flat_s.nbytes + flat_i.nbytes)
    else:
        cand_s = np.full((R, m, n_slots, k), -np.inf, dtype=np.float32)
        cand_i = np.full((R, m, n_slots, k), -1, dtype=np.int64)
        _account_candidates(stats, cand_s.nbytes + cand_i.nbytes)

    for lp in splan.pads:
        unit_lists, Q, valid, qrow_of, slot_of, Vrows, wmask = _assemble_bucket_stacked(
            splan, sharded, lp, q_vecs
        )
        V = np.zeros(valid.shape + (d,), dtype=np.float32)
        for r in range(R):
            if not unit_lists[r]:
                continue
            V[r] = arena.packed[Vrows[r]]
            sstats.per_rank_bytes[r] += len(unit_lists[r]) * lp * d * 4
            sstats.per_rank_dispatches[r] += 1
        if stats is not None:
            stats.bytes_scanned += int(sum(len(u) for u in unit_lists)) * lp * d * 4
        kk = min(k, lp)
        rank_units = [len(u) for u in unit_lists]
        prof = get_profiler()
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span(
            "dispatch.sharded", mode="f32", lp=lp, rank_units=rank_units,
        ):
            s, i_loc = kops.sharded_workunit_topk(
                mesh, axis,
                jnp.asarray(Q), jnp.asarray(V), jnp.asarray(valid), kk,
                metric=arena.metric,
                use_pallas=cfg.use_pallas, interpret=cfg.interpret,
            )
            s, i_loc = fence(s, i_loc)
        if prof.enabled:
            W_ = valid.shape[1]
            tq_ = splan.plan.tq
            nq_rw = wmask.sum(axis=2)  # [R, W]
            rows_rw = valid.sum(axis=2)  # [R, W]
            prof.record_dispatch(
                "scan", "sharded-f32", lp, t0,
                nbytes=Q.nbytes + V.nbytes + valid.nbytes
                + R * W_ * tq_ * kk * 12,
                flops=2.0 * d * float((nq_rw * rows_rw).sum()),
                flops_padded=2.0 * d * R * W_ * tq_ * lp,
                units=int(sum(rank_units)), units_padded=R * W_,
                rows=int(rows_rw.sum()), rows_padded=R * W_ * lp,
                rank_units=rank_units,
                rank_bytes=[n * lp * d * 4 for n in rank_units],
            )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # [R, W, tq, kk] index into the unit's lp rows
        for r in range(R):
            if not unit_lists[r]:
                continue
            packed_rows = np.take_along_axis(
                np.broadcast_to(Vrows[r][:, None, :], i_loc[r].shape[:2] + (lp,)),
                np.maximum(i_loc[r], 0),
                axis=2,
            )
            gidx = arena.gid[packed_rows]
            gidx = np.where(i_loc[r] < 0, -1, gidx)
            qr, sl = qrow_of[r][wmask[r]], slot_of[r][wmask[r]]
            if segmented:
                rows = base[r] + np.searchsorted(rank_keys[r], qr * S + sl)
                flat_s[rows, :kk] = s[r][wmask[r]]
                flat_i[rows, :kk] = gidx[wmask[r]]
            else:
                cand_s[r, qr, sl, :kk] = s[r][wmask[r]]
                cand_i[r, qr, sl, :kk] = gidx[wmask[r]]

    if segmented:
        # one ragged merge over R·m segments = every rank's local top-k; the
        # gather merge's rank-local reduction over these already-sorted rows
        # is an identity, so the all-gather sees the dense path's operands
        prof = get_profiler()
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span("merge.segmented", m=R * m, candidates=int(base[-1])):
            seg_s, seg_i = kops.segmented_merge_topk(
                jnp.asarray(flat_s), jnp.asarray(flat_i), jnp.asarray(seg_of), R * m, k
            )
            seg_s, seg_i = fence(seg_s, seg_i)
        if prof.enabled:
            prof.record_dispatch(
                "merge", "segmented", C_pad, t0,
                nbytes=flat_s.nbytes + flat_i.nbytes + seg_of.nbytes
                + R * m * k * 12,
                flops=0.0, flops_padded=0.0,
                units=R * m, units_padded=R * m,
                rows=int(base[-1]), rows_padded=C_pad,
            )
        ms, mi = _gather_merge(
            mesh, axis,
            np.asarray(seg_s, dtype=np.float32).reshape(R, m, 1, k),
            np.asarray(seg_i, dtype=np.int64).reshape(R, m, 1, k),
            k,
        )
    else:
        ms, mi = _gather_merge(mesh, axis, cand_s, cand_i, k)
    sstats.gathered_per_query += R * k
    return _merge_with_extras(ms, mi, extra, k)


def _execute_sharded_pq(
    splan: ShardedPlan,
    sharded: ShardedArena,
    q_vecs: np.ndarray,
    *,
    mesh,
    axis: str,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates],
    stats: Optional[ScanStats],
    sstats: ShardStats,
) -> Tuple[np.ndarray, np.ndarray]:
    """Compressed two-stage execution across the mesh.

    Stage A mirrors the f32 path with uint8 code tiles: per shared bucket,
    one sharded ADC dispatch; each rank keeps k′ = refine_factor · k ADC
    candidates per (query, posting list) as GLOBAL packed rows. The ADC
    candidate gather (k′·|model| per query) then selects the same global
    top-k′ the single-device merge would — any global survivor is also a
    local survivor on its rank — and stage B re-ranks exactly: every rank
    gathers the f32 rows of the candidates IT stores, scores them in one
    sharded dispatch, and the final k·|model| gather merges the partial
    exact top-ks. Extras fold in last, as everywhere.
    """
    R = sharded.n_shards
    m, k = splan.plan.m, splan.plan.k
    d = q_vecs.shape[1]
    arena = sharded.base
    kprime = max(k, int(cfg.refine_factor) * k)
    M = arena.codes.shape[1]

    used = np.unique(
        np.concatenate(
            [u.qrows for units in splan.plan.buckets.values() for u in units]
        )
    )
    lut_pos = np.zeros(m, dtype=np.int64)
    lut_pos[used] = np.arange(len(used))
    luts_dev = jnp.asarray(adc_tables(arena.pq, q_vecs[used]))  # [U, M, 256]
    _account_lut(stats, int(luts_dev.nbytes), expanded=False)

    n_slots = splan.plan.n_slots
    segmented = cfg.merge_layout == "segmented"
    if segmented:
        S, rank_keys, base, seg_pref = _rank_segments(splan, R, m)
        C_pad = _next_pow2(int(base[-1]), 1)
        flat_s = np.full((C_pad, kprime), -np.inf, dtype=np.float32)
        flat_rows = np.full((C_pad, kprime), -1, dtype=np.int64)
        seg_of = np.full(C_pad, R * m, dtype=np.int32)
        seg_of[: int(base[-1])] = seg_pref
        _account_candidates(stats, flat_s.nbytes + flat_rows.nbytes)
    else:
        cand_s = np.full((R, m, n_slots, kprime), -np.inf, dtype=np.float32)
        cand_rows = np.full((R, m, n_slots, kprime), -1, dtype=np.int64)
        _account_candidates(stats, cand_s.nbytes + cand_rows.nbytes)

    for lp in splan.pads:
        unit_lists, _, valid, qrow_of, slot_of, Vrows, wmask = _assemble_bucket_stacked(
            splan, sharded, lp, q_vecs, with_q=False
        )
        codes = np.zeros(valid.shape + (M,), dtype=np.uint8)
        for r in range(R):
            if not unit_lists[r]:
                continue
            codes[r] = arena.codes[Vrows[r]]
            sstats.per_rank_bytes[r] += len(unit_lists[r]) * lp * M
            sstats.per_rank_dispatches[r] += 1
        if stats is not None:
            stats.bytes_scanned += int(sum(len(u) for u in unit_lists)) * lp * M
        lut_idx = lut_pos[np.maximum(qrow_of, 0)]  # padding slots -> LUT row 0
        kk = min(kprime, lp)
        rank_units = [len(u) for u in unit_lists]
        if not segmented:
            # the dense dispatch expands per-unit [W, tq, M, 256] LUT operands
            # on every rank; the segmented (stream=True) dispatch indexes the
            # resident table from the kernel instead
            W = valid.shape[1]
            tq = splan.plan.tq
            _account_lut(
                stats, R * W * tq * M * 256 * 4, expanded=True
            )
        prof = get_profiler()
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span(
            "dispatch.sharded", mode="pq", lp=lp, rank_units=rank_units
        ):
            s, i_loc = kops.sharded_workunit_pq_topk(
                mesh, axis,
                luts_dev, jnp.asarray(lut_idx), jnp.asarray(codes), jnp.asarray(valid), kk,
                use_pallas=cfg.use_pallas, interpret=cfg.interpret,
                stream=segmented,
            )
            s, i_loc = fence(s, i_loc)
        if prof.enabled:
            W_ = valid.shape[1]
            tq_ = splan.plan.tq
            nq_rw = wmask.sum(axis=2)
            rows_rw = valid.sum(axis=2)
            lut_b = (int(nq_rw.sum()) * M * 256 * 4 if segmented
                     else R * W_ * tq_ * M * 256 * 4)
            prof.record_dispatch(
                "scan", "sharded-pq", lp, t0,
                nbytes=codes.nbytes + valid.nbytes + lut_b
                + R * W_ * tq_ * kk * 12,
                flops=2.0 * M * 256 * float((nq_rw * rows_rw).sum()),
                flops_padded=2.0 * M * 256 * R * W_ * tq_ * lp,
                units=int(sum(rank_units)), units_padded=R * W_,
                rows=int(rows_rw.sum()), rows_padded=R * W_ * lp,
                rank_units=rank_units,
                rank_bytes=[n * lp * M for n in rank_units],
            )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)
        for r in range(R):
            if not unit_lists[r]:
                continue
            packed_rows = np.take_along_axis(
                np.broadcast_to(Vrows[r][:, None, :], i_loc[r].shape[:2] + (lp,)),
                np.maximum(i_loc[r], 0),
                axis=2,
            )
            packed_rows = np.where(i_loc[r] < 0, -1, packed_rows)  # global rows
            qr, sl = qrow_of[r][wmask[r]], slot_of[r][wmask[r]]
            if segmented:
                rws = base[r] + np.searchsorted(rank_keys[r], qr * S + sl)
                flat_s[rws, :kk] = s[r][wmask[r]]
                flat_rows[rws, :kk] = packed_rows[wmask[r]]
            else:
                cand_s[r, qr, sl, :kk] = s[r][wmask[r]]
                cand_rows[r, qr, sl, :kk] = packed_rows[wmask[r]]

    # global top-k' ADC candidates: k'·|model| gather, identical selection to
    # the single-device merge (a global survivor survives locally too)
    if segmented:
        prof = get_profiler()
        t0 = prof.t0() if prof.enabled else 0
        with get_tracer().span("merge.segmented", m=R * m, candidates=int(base[-1])):
            seg_s, seg_i = kops.segmented_merge_topk(
                jnp.asarray(flat_s), jnp.asarray(flat_rows), jnp.asarray(seg_of),
                R * m, kprime,
            )
            seg_s, seg_i = fence(seg_s, seg_i)
        if prof.enabled:
            prof.record_dispatch(
                "merge", "segmented", C_pad, t0,
                nbytes=flat_s.nbytes + flat_rows.nbytes + seg_of.nbytes
                + R * m * kprime * 12,
                flops=0.0, flops_padded=0.0,
                units=R * m, units_padded=R * m,
                rows=int(base[-1]), rows_padded=C_pad,
            )
        _, top_rows = _gather_merge(
            mesh, axis,
            np.asarray(seg_s, dtype=np.float32).reshape(R, m, 1, kprime),
            np.asarray(seg_i, dtype=np.int64).reshape(R, m, 1, kprime),
            kprime,
        )
    else:
        _, top_rows = _gather_merge(mesh, axis, cand_s, cand_rows, kprime)
    sstats.gathered_per_query += R * kprime
    rows = top_rows  # [m, k'] global packed rows (-1 pad)

    # sharded exact re-rank: rank r rescans the surviving rows IT stores
    mp = _next_pow2(m, 1)
    Qr = np.zeros((R, mp, 1, d), dtype=np.float32)
    Qr[:, :m, 0] = q_vecs[None]
    Vr = np.zeros((R, mp, kprime, d), dtype=np.float32)
    valid_r = np.zeros((R, mp, kprime), dtype=bool)
    owner = sharded.owner_of_row(np.maximum(rows, 0))
    for r in range(R):
        own = (owner == r) & (rows >= 0)
        if not own.any():
            continue
        sel = arena.packed[rows[own]]
        Vr[r, :m][own] = sel
        valid_r[r, :m] = own
        sstats.per_rank_bytes[r] += sel.nbytes
        sstats.per_rank_dispatches[r] += 1
        if stats is not None:
            stats.bytes_scanned += sel.nbytes
    kk = min(k, kprime)
    prof = get_profiler()
    t0 = prof.t0() if prof.enabled else 0
    with get_tracer().span("rerank.exact", mode="sharded", m=m, kprime=kprime):
        s, i_loc = kops.sharded_workunit_topk(
            mesh, axis,
            jnp.asarray(Qr), jnp.asarray(Vr), jnp.asarray(valid_r), kk,
            metric=arena.metric,
            use_pallas=cfg.use_pallas, interpret=cfg.interpret,
        )
        s, i_loc = fence(s, i_loc)
    if prof.enabled:
        n_real = int(valid_r.sum())
        prof.record_dispatch(
            "rerank", "sharded", kprime, t0,
            nbytes=Qr.nbytes + Vr.nbytes + valid_r.nbytes + R * mp * kk * 12,
            flops=2.0 * d * n_real,
            flops_padded=2.0 * d * R * mp * kprime,
            units=m, units_padded=R * mp,
            rows=n_real, rows_padded=R * mp * kprime,
        )
    s = np.asarray(s)[:, :m, 0]  # [R, m, kk] exact partial scores
    i_loc = np.asarray(i_loc)[:, :m, 0]  # [R, m, kk] index into the k' candidates
    rows_b = np.broadcast_to(rows[None], (R, m, kprime))
    packed_rows = np.take_along_axis(
        rows_b, np.maximum(i_loc, 0).astype(np.int64), axis=2
    )
    gidx = np.where(i_loc < 0, -1, arena.gid[np.maximum(packed_rows, 0)])
    gidx = np.where(packed_rows < 0, -1, gidx)
    sc = np.where(gidx >= 0, s, -np.inf).astype(np.float32)

    ms, mi = _gather_merge(
        mesh, axis, sc[:, :, None, :], gidx[:, :, None, :], k
    )
    sstats.gathered_per_query += R * k
    return _merge_with_extras(ms, mi, extra, k)


def batch_search_ivf(
    ivf: IVFIndex,
    q_vecs: np.ndarray,  # [m, d] — one template group
    *,
    nprobe: int,
    k: int,
    bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
    stats: Optional[ScanStats] = None,
    cfg: Optional[PlanConfig] = None,
    pq: Optional[PQCodebook] = None,  # required iff cfg.scan_mode == "pq"
    mesh=None,  # jax.sharding.Mesh: shard the scan over its model axis
    shard_spec=None,  # core.distributed.ShardSpec (default axes)
) -> Tuple[np.ndarray, np.ndarray]:
    """Plan + execute one IVF index: (scores f32 [m, k], local idx i64 [m, k]).

    With ``mesh=`` the index is a single qd-tree-less partition, so sharding
    falls back to posting-list-block granularity: the arena's packed rows
    split into contiguous row slices per model rank (the single partition is
    viewed as |model| pseudo-partitions along posting-list boundaries) and
    execution runs through ``core.distributed.execute_sharded`` — results
    stay bit-identical to ``mesh=None``.
    """
    cfg = PlanConfig() if cfg is None else cfg
    m = q_vecs.shape[0]
    if m == 0:
        return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
    arena = PackedArena.from_ivf(ivf)
    if cfg.scan_mode == "pq":
        # explicit per-call codebook: the arena is memoized on the IVF, so
        # falling back to arena.pq would silently reuse whatever codebook a
        # PREVIOUS caller attached. Re-encoding is skipped when the same
        # codebook object is passed again (attach_pq is identity-idempotent).
        if pq is None:
            raise ValueError("batch_search_ivf(scan_mode='pq') needs an explicit pq=")
        arena.attach_pq(pq)
    packed_bitmap = None
    if bitmap is not None:
        packed_bitmap = arena.packed_bitmap(0, bitmap)
    task = EngineTask(
        part=0,
        qrows=np.arange(m, dtype=np.int64),
        nprobe=int(min(nprobe, ivf.n_lists)),
        packed_bitmap=packed_bitmap,
    )
    if mesh is not None:
        from .distributed import ShardSpec, execute_sharded

        spec = shard_spec or ShardSpec()
        sharded = PackedArena.sharded_from_ivf(ivf, spec.n_shards(mesh))
        s, i, _ = execute_sharded(
            sharded, [task], q_vecs,
            mesh=mesh, spec=spec, m=m, k=k, cfg=cfg, stats=stats,
        )
        return s, i
    plan = build_plan(arena, [task], q_vecs, m=m, k=k, cfg=cfg, stats=stats)
    return execute_plan(plan, arena, q_vecs, cfg=cfg, stats=stats)
