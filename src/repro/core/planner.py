"""Batch execution planner — the host side of Algorithm 3.

Given a group of queries (already grouped by attribute template — Alg. 3
line 5) and an IVF index, the planner:

  1. finds nprobe posting lists per query (line 6, one batched matmul),
  2. inverts the (query → lists) map into per-list query groups (line 8),
  3. packs (query-chunk × posting-list) pairs into fixed-shape *work units*
     bucketed by padded list length (static shapes for XLA/Pallas),
  4. executes all units of a bucket in one ``batched_masked_topk`` call —
     the single-matmul-per-posting-list of Alg. 3 line 10, fused with the
     Section 4.2 bitmap pushdown,
  5. scatters per-unit top-k back to a [m, nprobe, k] tensor and reduces it
     to the final per-query top-k (line 12's heap, as one top-k op).

Every (query, posting-list) pair is evaluated exactly once and each vector
lives in exactly one list, so results are identical to the per-query scan —
tests assert bit-equality of the candidate sets.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .ivf import IVFIndex, ScanStats


def _next_pow2(x: int, lo: int = 32) -> int:
    return max(lo, 1 << (max(1, x - 1)).bit_length())


@dataclasses.dataclass
class PlanConfig:
    tq_unit: int = 64  # queries per work unit
    min_list_pad: int = 32  # smallest padded list bucket
    use_pallas: Optional[bool] = None  # None = ops default
    interpret: Optional[bool] = None
    # adaptive executor (paper §6.5): below this group size the per-query
    # scan beats batched matmuls (Fig. 7a's crossover ≈ 100 at paper scale)
    adaptive_crossover: int = 64


def batch_search_ivf(
    ivf: IVFIndex,
    q_vecs: np.ndarray,  # [m, d] — one template group
    *,
    nprobe: int,
    k: int,
    bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
    stats: Optional[ScanStats] = None,
    cfg: PlanConfig = PlanConfig(),
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (scores f32 [m, k] best-first, local idx int64 [m, k]; -1 pad)."""
    m = q_vecs.shape[0]
    if m == 0:
        return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
    nprobe = int(min(nprobe, ivf.n_lists))
    probes = ivf.probe(q_vecs, nprobe)  # [m, nprobe]

    # bitmap in packed order (posting-list entries are contiguous slices)
    packed_bitmap = None
    if bitmap is not None:
        packed_bitmap = bitmap[ivf.order]

    # ---- invert (query, slot) -> list groups --------------------------------
    flat_list = probes.reshape(-1)  # [m * nprobe]
    flat_q = np.repeat(np.arange(m, dtype=np.int64), nprobe)
    flat_slot = np.tile(np.arange(nprobe, dtype=np.int64), m)
    sort = np.argsort(flat_list, kind="stable")
    flat_list, flat_q, flat_slot = flat_list[sort], flat_q[sort], flat_slot[sort]
    uniq_lists, group_starts = np.unique(flat_list, return_index=True)
    group_ends = np.append(group_starts[1:], len(flat_list))

    # ---- build work units, bucketed by padded list length -------------------
    buckets: Dict[Tuple[int, int], List[Tuple[int, np.ndarray, np.ndarray]]] = {}
    tq = cfg.tq_unit
    for l, gs, ge in zip(uniq_lists, group_starts, group_ends):
        llen = ivf.list_len(int(l))
        if llen == 0:
            continue
        lp = _next_pow2(llen, cfg.min_list_pad)
        qs, slots = flat_q[gs:ge], flat_slot[gs:ge]
        if stats is not None:
            stats.tuples_scanned += llen * len(qs)
            if packed_bitmap is not None:
                s0 = int(ivf.offsets[l])
                stats.dists_computed += int(packed_bitmap[s0 : s0 + llen].sum()) * len(qs)
            else:
                stats.dists_computed += llen * len(qs)
        for cs in range(0, len(qs), tq):
            buckets.setdefault((lp, tq), []).append((int(l), qs[cs : cs + tq], slots[cs : cs + tq]))

    out_scores = np.full((m, nprobe, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, nprobe, k), -1, dtype=np.int64)

    n_packed = ivf.n
    for (lp, _tq), units in buckets.items():
        W = len(units)
        Q = np.zeros((W, tq, q_vecs.shape[1]), dtype=np.float32)
        Vidx = np.zeros((W, lp), dtype=np.int64)
        valid = np.zeros((W, lp), dtype=bool)
        qrow_of = np.full((W, tq), -1, dtype=np.int64)
        slot_of = np.zeros((W, tq), dtype=np.int64)
        for w, (l, qs, slots) in enumerate(units):
            s0, e0 = int(ivf.offsets[l]), int(ivf.offsets[l + 1])
            llen = e0 - s0
            rows = np.arange(lp) + s0
            rows = np.minimum(rows, n_packed - 1)
            Vidx[w] = rows
            v_ok = np.arange(lp) < llen
            if packed_bitmap is not None:
                v_ok = v_ok & packed_bitmap[rows]
            valid[w] = v_ok
            Q[w, : len(qs)] = q_vecs[qs]
            qrow_of[w, : len(qs)] = qs
            slot_of[w, : len(qs)] = slots
        V = ivf.packed[Vidx]  # [W, lp, d]
        s, i_loc = kops.batched_masked_topk(
            jnp.asarray(Q),
            jnp.asarray(V),
            jnp.asarray(valid),
            min(k, lp),
            metric=ivf.metric,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # index within the unit's lp rows (-1 = none)
        kk = s.shape[-1]
        # local packed row -> local vector index
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vidx[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        gidx = ivf.order[packed_rows]
        gidx = np.where(i_loc < 0, -1, gidx)
        # scatter to [m, nprobe, k]
        wmask = qrow_of >= 0  # [W, tq]
        qr = qrow_of[wmask]
        sl = slot_of[wmask]
        out_scores[qr, sl, :kk] = s[wmask]
        out_idx[qr, sl, :kk] = gidx[wmask]

    # ---- final per-query merge (Alg. 3 line 12) -----------------------------
    flat_s = out_scores.reshape(m, -1)
    flat_i = out_idx.reshape(m, -1)
    kk = min(k, flat_s.shape[1])
    part = np.argpartition(-flat_s, kk - 1, axis=1)[:, :kk]
    top_s = np.take_along_axis(flat_s, part, axis=1)
    top_i = np.take_along_axis(flat_i, part, axis=1)
    ordr = np.argsort(-top_s, axis=1, kind="stable")
    top_s = np.take_along_axis(top_s, ordr, axis=1)
    top_i = np.take_along_axis(top_i, ordr, axis=1)
    if kk < k:
        top_s = np.pad(top_s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
        top_i = np.pad(top_i, ((0, 0), (0, k - kk)), constant_values=-1)
    # normalize sentinels: absent results are (-inf, -1) on every path
    top_s = np.where(top_i < 0, -np.inf, top_s)
    return top_s.astype(np.float32), top_i.astype(np.int64)
