"""Stage 2 of the execution engine: megabatched execution of a global plan.

``plan.py`` (stage 1) turns a whole workload into one ``ExecutionPlan`` whose
work units are bucketed by padded shape across every partition and template.
This module executes that plan:

  1. for each shape bucket, gather ALL its units' posting-list rows through
     the index-wide ``PackedArena`` (one gather serves every partition) and
     run them in a single ``kernels.ops.workunit_topk`` dispatch — the
     single-matmul-per-posting-list of Alg. 3 line 10, fused with the
     Section 4.2 bitmap pushdown, megabatched across the workload;
  2. scatter per-unit top-k into a [m, n_slots, k] candidate tensor, fold in
     any per-query scan results the adaptive executor produced host-side;
  3. reduce candidates to the final per-query top-k with ONE device-side
     segmented top-k (``ops.merge_topk``) — Alg. 3 line 12 for the whole
     workload, replacing the per-(template × partition) numpy merge loop.

Compressed execution (``PlanConfig.scan_mode="pq"``): the scan stage reads
the arena's uint8 PQ codes instead of raw f32 vectors — each bucket is one
``ops.workunit_pq_topk`` ADC dispatch producing ``refine_factor · k``
candidates per (query, posting list). Candidates from all buckets then merge
per query (one device merge), the survivors' f32 rows are gathered from the
arena ONCE, and a single ``workunit_topk`` dispatch re-ranks them exactly —
so dispatch cost stays O(#buckets) + 1 re-rank, never O(T×L), while scan HBM
traffic drops by d·4/M× (e.g. 32× at d=64, M=8). Bitmap pushdown composes
unchanged: the ADC stage applies the same ``valid`` mask, so re-rank
candidates already satisfy every predicate. The final merge still folds in
the adaptive executor's host-side (exact) candidates, which is sound because
re-ranked scores are exact too.

Dispatch cost is O(#buckets) ≤ ``PlanConfig.max_bucket_shapes`` instead of
O(T×L). In f32 mode every (query, posting-list) pair is evaluated exactly
once and each vector lives in exactly one list, so results are identical to
the per-query scan — tests assert equality of scores and candidate sets. In
pq mode that uniqueness also means the candidate union is duplicate-free.

Known scale tradeoff: the merge tensor is dense [m, n_slots, k] where
``n_slots`` is the *max* per-query slot count over the workload, so queries
routed to few partitions pay for the widest query's slots. At very large
m × n_slots a segmented (ragged) candidate layout would cut peak memory —
a natural follow-up once sharded serving (ROADMAP) lands.

``batch_search_ivf`` survives as the single-index entry point (used by the
baselines and benchmarks): it wraps the index in a one-partition arena,
builds a one-task plan, and executes it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .arena import PackedArena
from .ivf import IVFIndex, ScanStats
from .plan import EngineTask, ExecutionPlan, PlanConfig, WorkUnit, build_plan, _next_pow2
from .pq import PQCodebook, adc_tables

# Extra per-query candidates merged alongside the plan's output (the adaptive
# executor's host-side scans): (qrows i64 [mq], scores f32 [mq, k], ids i64 [mq, k])
ExtraCandidates = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _assemble_bucket(
    units: List[WorkUnit],
    lp: int,
    plan: ExecutionPlan,
    arena: PackedArena,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Shared scan-stage assembly for one shape bucket.

    Returns (Vrows i64 [W, lp] packed rows to gather, valid bool [W, lp],
    qrow_of i64 [W, tq] workload query row per unit slot (-1 pad),
    slot_of i64 [W, tq] merge-tensor slot per unit slot). W is the unit count
    padded to a power of two so repeated workloads reuse a bounded set of
    compiled shapes (padding units are fully masked).
    """
    tq = plan.tq
    n_packed = arena.n
    W = _next_pow2(len(units), 1)
    Vrows = np.zeros((W, lp), dtype=np.int64)
    valid = np.zeros((W, lp), dtype=bool)
    qrow_of = np.full((W, tq), -1, dtype=np.int64)
    slot_of = np.zeros((W, tq), dtype=np.int64)
    for w, u in enumerate(units):
        s0 = int(arena.list_start[u.glist])
        llen = int(arena.list_len[u.glist])
        rows = np.minimum(np.arange(lp) + s0, n_packed - 1)
        Vrows[w] = rows
        v_ok = np.arange(lp) < llen
        task = plan.tasks[u.task]
        if task.packed_bitmap is not None:
            pb = task.packed_bitmap
            local = np.minimum(rows - int(arena.part_row[task.part]), len(pb) - 1)
            v_ok = v_ok & pb[local]
        valid[w] = v_ok
        nq = len(u.qrows)
        qrow_of[w, :nq] = u.qrows
        slot_of[w, :nq] = u.slots
    return Vrows, valid, qrow_of, slot_of


def execute_plan(
    plan: ExecutionPlan,
    arena: Optional[PackedArena],  # None allowed iff the plan has no buckets
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    cfg: Optional[PlanConfig] = None,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (scores f32 [m, k] best-first, arena gids i64 [m, k]; -1 pad)."""
    cfg = PlanConfig() if cfg is None else cfg
    if cfg.scan_mode == "pq" and plan.buckets:
        if arena.codes is None or arena.pq is None:
            raise ValueError(
                "scan_mode='pq' needs a PQ-encoded arena: build the HQIIndex "
                "with HQIConfig(scan_mode='pq'), or pass pq= to "
                "batch_search_ivf; baseline indexes support scan_mode='f32' only"
            )
        return _execute_plan_pq(plan, arena, q_vecs, cfg=cfg, extra=extra, stats=stats)
    if cfg.scan_mode not in ("f32", "pq"):
        raise ValueError(f"unknown scan_mode {cfg.scan_mode!r}")
    m, k, tq = plan.m, plan.k, plan.tq
    # extras get per-query-dense slot columns after the plan's own slots
    n_slots = plan.n_slots + _extra_slot_width(extra, m)
    if m == 0 or n_slots == 0:
        return (
            np.full((m, k), -np.inf, np.float32),
            np.full((m, k), -1, np.int64),
        )

    out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
    d = q_vecs.shape[1]

    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        Vrows, valid, qrow_of, slot_of = _assemble_bucket(units, lp, plan, arena)
        W = Vrows.shape[0]
        Q = np.zeros((W, tq, d), dtype=np.float32)
        wmask = qrow_of >= 0  # [W, tq]
        Q[wmask] = q_vecs[qrow_of[wmask]]
        V = arena.packed[Vrows]  # [W, lp, d] — one gather across all partitions
        if stats is not None:
            stats.bytes_scanned += V.nbytes
        s, i_loc = kops.workunit_topk(
            jnp.asarray(Q),
            jnp.asarray(V),
            jnp.asarray(valid),
            min(k, lp),
            metric=arena.metric,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # index within the unit's lp rows (-1 = none)
        kk = s.shape[-1]
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        gidx = arena.gid[packed_rows]
        gidx = np.where(i_loc < 0, -1, gidx)
        qr = qrow_of[wmask]
        sl = slot_of[wmask]
        out_scores[qr, sl, :kk] = s[wmask]
        out_idx[qr, sl, :kk] = gidx[wmask]

    return _fold_extras_and_merge(out_scores, out_idx, extra, plan.n_slots, k)


def _extra_slot_width(extra: Sequence[ExtraCandidates], m: int) -> int:
    """Max per-query count of host-side extra candidate columns."""
    extra_slots = np.zeros(m, dtype=np.int64)
    for qrows, _, _ in extra:
        extra_slots[qrows] += 1
    return int(extra_slots.max()) if m else 0


def _fold_extras_and_merge(
    out_scores: np.ndarray,  # f32 [m, n_slots, k] — base candidates filled in
    out_idx: np.ndarray,  # i64 [m, n_slots, k]
    extra: Sequence[ExtraCandidates],
    base_slots: int,  # extras occupy slot columns base_slots, base_slots+1, ...
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fold the adaptive executor's host-side candidates in, then final-merge.

    Shared tail of both scan modes, so extras handling can never diverge
    between the f32 and pq paths.
    """
    m = out_scores.shape[0]
    next_extra = np.full(m, base_slots, dtype=np.int64)
    for qrows, es, ei in extra:
        kk = min(k, es.shape[1])
        slot = next_extra[qrows]
        next_extra[qrows] += 1
        out_scores[qrows, slot, :kk] = es[:, :kk]
        out_idx[qrows, slot, :kk] = ei[:, :kk]
    top_s, top_i = _padded_merge(out_scores.reshape(m, -1), out_idx.reshape(m, -1), k)
    return np.asarray(top_s, dtype=np.float32), np.asarray(top_i, dtype=np.int64)


def _padded_merge(
    flat_s: np.ndarray, flat_i: np.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """merge_topk with the candidate width padded to a power of two (so
    repeated workloads reuse a bounded set of compiled merge shapes)."""
    width = _next_pow2(flat_s.shape[1], k)
    if width > flat_s.shape[1]:
        padc = width - flat_s.shape[1]
        flat_s = np.pad(flat_s, ((0, 0), (0, padc)), constant_values=-np.inf)
        flat_i = np.pad(flat_i, ((0, 0), (0, padc)), constant_values=-1)
    return kops.merge_topk(jnp.asarray(flat_s), jnp.asarray(flat_i), k)


def _execute_plan_pq(
    plan: ExecutionPlan,
    arena: PackedArena,
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    cfg: PlanConfig,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Compressed two-stage execution: ADC scan over codes, then exact re-rank.

    Stage A — per shape bucket, ONE ``workunit_pq_topk`` dispatch scans uint8
    code tiles with each unit's VMEM-resident per-query LUTs, keeping
    k′ = refine_factor · k ADC candidates per (query, posting list).
    Stage B — candidates from all buckets merge to the per-query top-k′ (one
    device merge over ADC scores), their f32 rows are gathered from the arena
    once, and ONE ``workunit_topk`` dispatch re-scores them exactly. The
    final merge then folds in the adaptive executor's host-side candidates,
    exactly like the f32 path.
    """
    m, k, tq = plan.m, plan.k, plan.tq
    d = q_vecs.shape[1]
    kprime = max(k, int(cfg.refine_factor) * k)

    # ADC tables only for queries the plan actually scans (the adaptive
    # executor may have routed most of the workload to host-side extras),
    # shipped to the device ONCE; each bucket's per-unit [W, tq, M, 256]
    # operand is expanded by a device-side gather, so the host never
    # materializes the replicated tables and every dispatch reuses the same
    # resident [U, M, 256] array. (Streaming LUT rows inside the kernel via
    # scalar-prefetch index maps would kill the device-side expansion too —
    # see ROADMAP.)
    used = np.unique(
        np.concatenate(
            [u.qrows for units in plan.buckets.values() for u in units]
        )
    )
    lut_pos = np.zeros(m, dtype=np.int64)
    lut_pos[used] = np.arange(len(used))
    luts_dev = jnp.asarray(adc_tables(arena.pq, q_vecs[used]))  # [U, M, 256]

    cand_s = np.full((m, plan.n_slots, kprime), -np.inf, dtype=np.float32)
    cand_rows = np.full((m, plan.n_slots, kprime), -1, dtype=np.int64)

    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        Vrows, valid, qrow_of, slot_of = _assemble_bucket(units, lp, plan, arena)
        W = Vrows.shape[0]
        wmask = qrow_of >= 0
        # padding slots map to LUT row 0; their outputs are dropped via wmask
        luts = jnp.take(
            luts_dev, jnp.asarray(lut_pos[np.maximum(qrow_of, 0)]), axis=0
        )  # [W, tq, M, 256], gathered on device
        codes = arena.codes[Vrows]  # [W, lp, M] uint8 — the compressed gather
        if stats is not None:
            stats.bytes_scanned += codes.nbytes
        kk = min(kprime, lp)
        s, i_loc = kops.workunit_pq_topk(
            jnp.asarray(luts),
            jnp.asarray(codes),
            jnp.asarray(valid),
            kk,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # [W, tq, kk] index into the unit's lp rows
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        packed_rows = np.where(i_loc < 0, -1, packed_rows)
        qr = qrow_of[wmask]
        sl = slot_of[wmask]
        cand_s[qr, sl, :kk] = s[wmask]
        cand_rows[qr, sl, :kk] = packed_rows[wmask]

    # per-query top-k' ADC candidates across every bucket and probe slot
    top_cs, top_rows = _padded_merge(
        cand_s.reshape(m, -1), cand_rows.reshape(m, -1), kprime
    )
    rows = np.asarray(top_rows, dtype=np.int64)  # [m, k'] packed rows (-1 pad)

    # exact re-rank: one gather of the surviving f32 rows + one dispatch.
    # Units are per-query (TQ=1) so each query re-scores only ITS candidates;
    # m pads to a power of two for compile-shape reuse.
    mp = _next_pow2(m, 1)
    Qr = np.zeros((mp, 1, d), dtype=np.float32)
    Qr[:m, 0] = q_vecs
    Vr = np.zeros((mp, kprime, d), dtype=np.float32)
    Vr[:m] = arena.packed[np.maximum(rows, 0)]
    valid_r = np.zeros((mp, kprime), dtype=bool)
    valid_r[:m] = rows >= 0
    if stats is not None:
        stats.bytes_scanned += Vr[:m].nbytes
    s, i_loc = kops.workunit_topk(
        jnp.asarray(Qr),
        jnp.asarray(Vr),
        jnp.asarray(valid_r),
        min(k, kprime),
        metric=arena.metric,
        use_pallas=cfg.use_pallas,
        interpret=cfg.interpret,
    )
    s = np.asarray(s)[:m, 0]  # [m, kk] exact scores
    i_loc = np.asarray(i_loc)[:m, 0]  # [m, kk] index into the k' candidates
    kk = s.shape[-1]
    packed_rows = np.take_along_axis(rows, np.maximum(i_loc, 0).astype(np.int64), axis=1)
    gidx = np.where(i_loc < 0, -1, arena.gid[np.maximum(packed_rows, 0)])
    gidx = np.where(packed_rows < 0, -1, gidx)

    # final merge: re-ranked (exact) plan results in slot 0 + host-side exact
    # extras in the columns after it — the same tail as the f32 path
    n_slots = 1 + _extra_slot_width(extra, m)
    out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
    out_scores[:, 0, :kk] = np.where(gidx >= 0, s, -np.inf)
    out_idx[:, 0, :kk] = gidx
    return _fold_extras_and_merge(out_scores, out_idx, extra, 1, k)


def batch_search_ivf(
    ivf: IVFIndex,
    q_vecs: np.ndarray,  # [m, d] — one template group
    *,
    nprobe: int,
    k: int,
    bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
    stats: Optional[ScanStats] = None,
    cfg: Optional[PlanConfig] = None,
    pq: Optional[PQCodebook] = None,  # required iff cfg.scan_mode == "pq"
) -> Tuple[np.ndarray, np.ndarray]:
    """Plan + execute one IVF index: (scores f32 [m, k], local idx i64 [m, k])."""
    cfg = PlanConfig() if cfg is None else cfg
    m = q_vecs.shape[0]
    if m == 0:
        return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
    arena = PackedArena.from_ivf(ivf)
    if cfg.scan_mode == "pq":
        # explicit per-call codebook: the arena is memoized on the IVF, so
        # falling back to arena.pq would silently reuse whatever codebook a
        # PREVIOUS caller attached. Re-encoding is skipped when the same
        # codebook object is passed again (attach_pq is identity-idempotent).
        if pq is None:
            raise ValueError("batch_search_ivf(scan_mode='pq') needs an explicit pq=")
        arena.attach_pq(pq)
    packed_bitmap = None
    if bitmap is not None:
        packed_bitmap = arena.packed_bitmap(0, bitmap)
    task = EngineTask(
        part=0,
        qrows=np.arange(m, dtype=np.int64),
        nprobe=int(min(nprobe, ivf.n_lists)),
        packed_bitmap=packed_bitmap,
    )
    plan = build_plan(arena, [task], q_vecs, m=m, k=k, cfg=cfg, stats=stats)
    return execute_plan(plan, arena, q_vecs, cfg=cfg, stats=stats)
