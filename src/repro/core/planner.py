"""Stage 2 of the execution engine: megabatched execution of a global plan.

``plan.py`` (stage 1) turns a whole workload into one ``ExecutionPlan`` whose
work units are bucketed by padded shape across every partition and template.
This module executes that plan:

  1. for each shape bucket, gather ALL its units' posting-list rows through
     the index-wide ``PackedArena`` (one gather serves every partition) and
     run them in a single ``kernels.ops.workunit_topk`` dispatch — the
     single-matmul-per-posting-list of Alg. 3 line 10, fused with the
     Section 4.2 bitmap pushdown, megabatched across the workload;
  2. scatter per-unit top-k into a [m, n_slots, k] candidate tensor, fold in
     any per-query scan results the adaptive executor produced host-side;
  3. reduce candidates to the final per-query top-k with ONE device-side
     segmented top-k (``ops.merge_topk``) — Alg. 3 line 12 for the whole
     workload, replacing the per-(template × partition) numpy merge loop.

Dispatch cost is O(#buckets) ≤ ``PlanConfig.max_bucket_shapes`` instead of
O(T×L). Every (query, posting-list) pair is evaluated exactly once and each
vector lives in exactly one list, so results are identical to the per-query
scan — tests assert equality of scores and candidate sets.

Known scale tradeoff: the merge tensor is dense [m, n_slots, k] where
``n_slots`` is the *max* per-query slot count over the workload, so queries
routed to few partitions pay for the widest query's slots. At very large
m × n_slots a segmented (ragged) candidate layout would cut peak memory —
a natural follow-up once sharded serving (ROADMAP) lands.

``batch_search_ivf`` survives as the single-index entry point (used by the
baselines and benchmarks): it wraps the index in a one-partition arena,
builds a one-task plan, and executes it.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .arena import PackedArena
from .ivf import IVFIndex, ScanStats
from .plan import EngineTask, ExecutionPlan, PlanConfig, build_plan, _next_pow2

# Extra per-query candidates merged alongside the plan's output (the adaptive
# executor's host-side scans): (qrows i64 [mq], scores f32 [mq, k], ids i64 [mq, k])
ExtraCandidates = Tuple[np.ndarray, np.ndarray, np.ndarray]


def execute_plan(
    plan: ExecutionPlan,
    arena: Optional[PackedArena],  # None allowed iff the plan has no buckets
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    cfg: Optional[PlanConfig] = None,
    extra: Sequence[ExtraCandidates] = (),
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (scores f32 [m, k] best-first, arena gids i64 [m, k]; -1 pad)."""
    cfg = PlanConfig() if cfg is None else cfg
    m, k, tq = plan.m, plan.k, plan.tq
    # extras get per-query-dense slot columns after the plan's own slots
    extra_slots = np.zeros(m, dtype=np.int64)
    for qrows, _, _ in extra:
        extra_slots[qrows] += 1
    n_slots = plan.n_slots + (int(extra_slots.max()) if m else 0)
    if m == 0 or n_slots == 0:
        return (
            np.full((m, k), -np.inf, np.float32),
            np.full((m, k), -1, np.int64),
        )

    out_scores = np.full((m, n_slots, k), -np.inf, dtype=np.float32)
    out_idx = np.full((m, n_slots, k), -1, dtype=np.int64)
    d = q_vecs.shape[1]

    n_packed = arena.n if plan.buckets else 0
    for lp in sorted(plan.buckets):
        units = plan.buckets[lp]
        # pad the unit count to a power of two so repeated workloads reuse a
        # bounded set of compiled shapes (padding units are fully masked)
        W = _next_pow2(len(units), 1)
        Q = np.zeros((W, tq, d), dtype=np.float32)
        Vrows = np.zeros((W, lp), dtype=np.int64)
        valid = np.zeros((W, lp), dtype=bool)
        qrow_of = np.full((W, tq), -1, dtype=np.int64)
        slot_of = np.zeros((W, tq), dtype=np.int64)
        for w, u in enumerate(units):
            s0 = int(arena.list_start[u.glist])
            llen = int(arena.list_len[u.glist])
            rows = np.minimum(np.arange(lp) + s0, n_packed - 1)
            Vrows[w] = rows
            v_ok = np.arange(lp) < llen
            task = plan.tasks[u.task]
            if task.packed_bitmap is not None:
                pb = task.packed_bitmap
                local = np.minimum(rows - int(arena.part_row[task.part]), len(pb) - 1)
                v_ok = v_ok & pb[local]
            valid[w] = v_ok
            nq = len(u.qrows)
            Q[w, :nq] = q_vecs[u.qrows]
            qrow_of[w, :nq] = u.qrows
            slot_of[w, :nq] = u.slots
        V = arena.packed[Vrows]  # [W, lp, d] — one gather across all partitions
        s, i_loc = kops.workunit_topk(
            jnp.asarray(Q),
            jnp.asarray(V),
            jnp.asarray(valid),
            min(k, lp),
            metric=arena.metric,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        s = np.asarray(s)
        i_loc = np.asarray(i_loc)  # index within the unit's lp rows (-1 = none)
        kk = s.shape[-1]
        packed_rows = np.take_along_axis(
            np.broadcast_to(Vrows[:, None, :], i_loc.shape[:2] + (lp,)),
            np.maximum(i_loc, 0),
            axis=2,
        )
        gidx = arena.gid[packed_rows]
        gidx = np.where(i_loc < 0, -1, gidx)
        wmask = qrow_of >= 0  # [W, tq]
        qr = qrow_of[wmask]
        sl = slot_of[wmask]
        out_scores[qr, sl, :kk] = s[wmask]
        out_idx[qr, sl, :kk] = gidx[wmask]

    next_extra = np.full(m, plan.n_slots, dtype=np.int64)
    for qrows, es, ei in extra:
        kk = min(k, es.shape[1])
        slot = next_extra[qrows]
        next_extra[qrows] += 1
        out_scores[qrows, slot, :kk] = es[:, :kk]
        out_idx[qrows, slot, :kk] = ei[:, :kk]

    # pad the merge width to a power of two so repeated workloads reuse a
    # bounded set of compiled merge shapes
    flat_s = out_scores.reshape(m, -1)
    flat_i = out_idx.reshape(m, -1)
    width = _next_pow2(flat_s.shape[1], k)
    if width > flat_s.shape[1]:
        padc = width - flat_s.shape[1]
        flat_s = np.pad(flat_s, ((0, 0), (0, padc)), constant_values=-np.inf)
        flat_i = np.pad(flat_i, ((0, 0), (0, padc)), constant_values=-1)
    top_s, top_i = kops.merge_topk(jnp.asarray(flat_s), jnp.asarray(flat_i), k)
    return np.asarray(top_s, dtype=np.float32), np.asarray(top_i, dtype=np.int64)


def batch_search_ivf(
    ivf: IVFIndex,
    q_vecs: np.ndarray,  # [m, d] — one template group
    *,
    nprobe: int,
    k: int,
    bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
    stats: Optional[ScanStats] = None,
    cfg: Optional[PlanConfig] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Plan + execute one IVF index: (scores f32 [m, k], local idx i64 [m, k])."""
    cfg = PlanConfig() if cfg is None else cfg
    m = q_vecs.shape[0]
    if m == 0:
        return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
    arena = PackedArena.from_ivf(ivf)
    packed_bitmap = None
    if bitmap is not None:
        packed_bitmap = arena.packed_bitmap(0, bitmap)
    task = EngineTask(
        part=0,
        qrows=np.arange(m, dtype=np.int64),
        nprobe=int(min(nprobe, ivf.n_lists)),
        packed_bitmap=packed_bitmap,
    )
    plan = build_plan(arena, [task], q_vecs, m=m, k=k, cfg=cfg, stats=stats)
    return execute_plan(plan, arena, q_vecs, cfg=cfg)
