"""Evaluation metrics + nprobe tuning (Section 6.1's protocol).

recall@k against exhaustive ground truth; per-template nprobe tuned (doubling
search) until the target recall is reached — the paper tunes nprobe per query
template for Recall ≥ 0.8 at k = 10.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from .types import SearchResult, Workload


def _hits_totals(result: SearchResult, truth: SearchResult) -> tuple:
    """Per-query (retrieved-truth count, truth count), set-free.

    One broadcasted [m, k_truth, k_result] id comparison replaces the Python
    per-query set loop — this sits inside ``tune_nprobe``'s doubling search,
    so it runs O(T · log nprobe) times per tuning pass. Ids within a row are
    distinct (top-k over distinct tuples; -1 padding is masked out), so the
    any-match reduction counts each hit exactly once.
    """
    t = np.asarray(truth.ids)
    r = np.asarray(result.ids)
    t_ok = t >= 0  # [m, kt]
    match = (t[:, :, None] == r[:, None, :]) & t_ok[:, :, None] & (r >= 0)[:, None, :]
    hits = match.any(axis=2).sum(axis=1)  # [m]
    return hits.astype(np.int64), t_ok.sum(axis=1).astype(np.int64)


def recall_at_k(result: SearchResult, truth: SearchResult) -> float:
    """Fraction of ground-truth ids retrieved (micro-averaged over queries)."""
    hits, totals = _hits_totals(result, truth)
    return float(hits.sum()) / max(int(totals.sum()), 1)


def per_template_recall(result: SearchResult, truth: SearchResult, workload: Workload) -> Dict[int, float]:
    hits, totals = _hits_totals(result, truth)
    out = {}
    for ti in range(len(workload.templates)):
        qidx = workload.queries_for_template(ti)
        if len(qidx) == 0:
            continue
        out[ti] = float(hits[qidx].sum()) / max(int(totals[qidx].sum()), 1)
    return out


def tune_nprobe(
    search_fn: Callable[[Workload, Dict[int, int]], SearchResult],
    workload: Workload,
    truth: SearchResult,
    *,
    target_recall: float = 0.8,
    max_nprobe: int = 256,
    sample_per_template: int = 64,
    seed: int = 0,
) -> Dict[int, int]:
    """Per-template nprobe via doubling search on a query sample."""
    rng = np.random.default_rng(seed)
    nprobe: Dict[int, int] = {}
    for ti in range(len(workload.templates)):
        qidx = workload.queries_for_template(ti)
        if len(qidx) == 0:
            nprobe[ti] = 1
            continue
        if len(qidx) > sample_per_template:
            qidx = rng.choice(qidx, size=sample_per_template, replace=False)
        sub = workload.subset(qidx)
        sub_truth = SearchResult(ids=truth.ids[qidx], scores=truth.scores[qidx])
        # double 1, 2, 4, … but clamp the ladder's top rung AT max_nprobe so
        # the value returned is always one that was actually evaluated — a
        # non-power-of-two cap (say 100) is probed itself, never returned
        # sight-unseen after probing only 64
        np_t = 1
        while True:
            res = search_fn(sub, {0: np_t})
            if recall_at_k(res, sub_truth) >= target_recall or np_t >= max_nprobe:
                break
            np_t = min(np_t * 2, max_nprobe)
        nprobe[ti] = np_t
    return nprobe
