"""Evaluation metrics + nprobe tuning (Section 6.1's protocol).

recall@k against exhaustive ground truth; per-template nprobe tuned (doubling
search) until the target recall is reached — the paper tunes nprobe per query
template for Recall ≥ 0.8 at k = 10.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from .types import SearchResult, Workload


def recall_at_k(result: SearchResult, truth: SearchResult) -> float:
    """Fraction of ground-truth ids retrieved (averaged over queries)."""
    m, k = truth.ids.shape
    hits = 0
    total = 0
    for i in range(m):
        t = set(int(x) for x in truth.ids[i] if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in result.ids[i] if x >= 0)
        hits += len(t & r)
        total += len(t)
    return hits / max(total, 1)


def per_template_recall(result: SearchResult, truth: SearchResult, workload: Workload) -> Dict[int, float]:
    out = {}
    for ti in range(len(workload.templates)):
        qidx = workload.queries_for_template(ti)
        if len(qidx) == 0:
            continue
        sub_r = SearchResult(ids=result.ids[qidx], scores=result.scores[qidx])
        sub_t = SearchResult(ids=truth.ids[qidx], scores=truth.scores[qidx])
        out[ti] = recall_at_k(sub_r, sub_t)
    return out


def tune_nprobe(
    search_fn: Callable[[Workload, Dict[int, int]], SearchResult],
    workload: Workload,
    truth: SearchResult,
    *,
    target_recall: float = 0.8,
    max_nprobe: int = 256,
    sample_per_template: int = 64,
    seed: int = 0,
) -> Dict[int, int]:
    """Per-template nprobe via doubling search on a query sample."""
    rng = np.random.default_rng(seed)
    nprobe: Dict[int, int] = {}
    for ti in range(len(workload.templates)):
        qidx = workload.queries_for_template(ti)
        if len(qidx) == 0:
            nprobe[ti] = 1
            continue
        if len(qidx) > sample_per_template:
            qidx = rng.choice(qidx, size=sample_per_template, replace=False)
        sub = workload.subset(qidx)
        sub_truth = SearchResult(ids=truth.ids[qidx], scores=truth.scores[qidx])
        np_t = 1
        while np_t <= max_nprobe:
            res = search_fn(sub, {0: np_t})
            if recall_at_k(res, sub_truth) >= target_recall:
                break
            np_t *= 2
        nprobe[ti] = min(np_t, max_nprobe)
    return nprobe
