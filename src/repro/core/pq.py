"""Product quantization (Jégou et al. 2011) — the compressed-index companion

to IVF used by the paper's FAISS baseline family (IVF-PQ).

Vectors are split into M subvectors, each quantized against a 256-entry
codebook → codes are [n, M] uint8 (d·4 / M bytes ⇒ e.g. 32× compression at
d=64, M=8). Asymmetric distance computation (ADC): per query, precompute a
[M, 256] lookup table of partial distances; a database vector's score is a
sum of M table lookups — no float vector ever read at scan time.

TPU adaptation: the LUT (M·256·4 B ≤ 64 KB) lives in VMEM; the scan is a
gather+accumulate over uint8 code tiles fused with the same running top-k
as fused_knn (kernels/pq_scan.py). Bitmap pushdown composes unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kmeans as km


@dataclasses.dataclass
class PQCodebook:
    centroids: np.ndarray  # f32 [M, 256, dsub]
    metric: str

    @property
    def m(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def dsub(self) -> int:
        return int(self.centroids.shape[2])

    @property
    def d(self) -> int:
        """Vector dimensionality this codebook encodes (m · dsub)."""
        return self.m * self.dsub

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): arrays stay np.ndarray leaves."""
        return {"metric": self.metric, "centroids": self.centroids}

    @staticmethod
    def from_state(state: dict) -> "PQCodebook":
        return PQCodebook(
            centroids=np.asarray(state["centroids"]), metric=state["metric"]
        )


def train_pq(
    vectors: np.ndarray,
    m: int = 8,
    *,
    nbits: int = 8,
    iters: int = 8,
    metric: str = "l2",
    seed: int = 0,
    sample_cap: int = 65_536,
) -> PQCodebook:
    n, d = vectors.shape
    assert d % m == 0, f"d={d} not divisible by M={m}"
    k = 1 << nbits
    dsub = d // m
    rng = np.random.default_rng(seed)
    if n > sample_cap:
        vectors = vectors[rng.choice(n, sample_cap, replace=False)]
    cents = np.empty((m, k, dsub), np.float32)
    for j in range(m):
        sub = np.ascontiguousarray(vectors[:, j * dsub : (j + 1) * dsub])
        cents[j] = km.train_kmeans(sub, k, iters=iters, metric="l2", seed=seed + j)
    return PQCodebook(centroids=cents, metric=metric)


def encode_pq(cb: PQCodebook, vectors: np.ndarray) -> np.ndarray:
    """uint8 codes [n, M]."""
    n, d = vectors.shape
    if d != cb.d:
        raise ValueError(
            f"PQ codebook shape mismatch: codebook encodes d={cb.d} "
            f"(m={cb.m} subspaces × dsub={cb.dsub}), vectors have d={d}"
        )
    dsub = cb.dsub
    codes = np.empty((n, cb.m), np.uint8)
    for j in range(cb.m):
        sub = np.ascontiguousarray(vectors[:, j * dsub : (j + 1) * dsub])
        codes[:, j] = km.assign_kmeans(sub, cb.centroids[j], metric="l2").astype(np.uint8)
    return codes


def decode_pq(cb: PQCodebook, codes: np.ndarray) -> np.ndarray:
    """Reconstruction (for re-ranking / tests)."""
    n = codes.shape[0]
    out = np.empty((n, cb.m * cb.dsub), np.float32)
    for j in range(cb.m):
        out[:, j * cb.dsub : (j + 1) * cb.dsub] = cb.centroids[j][codes[:, j]]
    return out


def adc_tables(cb: PQCodebook, queries: np.ndarray) -> np.ndarray:
    """Per-query partial-score LUTs: f32 [nq, M, 256], higher = better.

    l2: -‖q_sub − c‖² summed over subspaces == -‖q − decode(code)‖².
    ip: q_sub · c summed == q · decode(code).
    """
    nq = queries.shape[0]
    dsub = cb.dsub
    luts = np.empty((nq, cb.m, cb.centroids.shape[1]), np.float32)
    for j in range(cb.m):
        qs = queries[:, j * dsub : (j + 1) * dsub]  # [nq, dsub]
        c = cb.centroids[j]  # [256, dsub]
        ip = qs @ c.T
        if cb.metric == "l2":
            luts[:, j] = 2 * ip - (qs * qs).sum(1, keepdims=True) - (c * c).sum(1)[None, :]
        else:
            luts[:, j] = ip
    return luts


def adc_scan_ref(
    luts: jax.Array,  # f32 [nq, M, 256]
    codes: jax.Array,  # uint8/int32 [nv, M]
    valid: jax.Array,  # bool [nv]
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle ADC scan: scores [nq, nv] = Σ_m lut[q, m, code[v, m]] → top-k."""
    from ..kernels.ref import NEG_INF

    c = codes.astype(jnp.int32)  # [nv, M]
    # gather per subspace then sum: [nq, nv]
    scores = jnp.zeros((luts.shape[0], codes.shape[0]), jnp.float32)
    for j in range(luts.shape[1]):
        scores = scores + luts[:, j, :][:, c[:, j]]
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    top, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(top <= NEG_INF / 2, -1, idx).astype(jnp.int32)
    return top, idx


@dataclasses.dataclass
class PQIndex:
    """Flat PQ index with ADC scan + optional exact re-ranking."""

    cb: PQCodebook
    codes: np.ndarray  # [n, M] uint8
    vectors: Optional[np.ndarray] = None  # kept for re-ranking if provided

    @staticmethod
    def build(vectors: np.ndarray, m: int = 8, *, metric: str = "l2", keep_vectors: bool = True, seed: int = 0) -> "PQIndex":
        cb = train_pq(vectors, m, metric=metric, seed=seed)
        codes = encode_pq(cb, vectors)
        return PQIndex(cb=cb, codes=codes, vectors=vectors if keep_vectors else None)

    def search(
        self,
        queries: np.ndarray,
        k: int,
        *,
        bitmap: Optional[np.ndarray] = None,
        rerank: int = 0,  # fetch rerank·k ADC candidates, re-score exactly
    ) -> Tuple[np.ndarray, np.ndarray]:
        n = self.codes.shape[0]
        valid = jnp.asarray(bitmap if bitmap is not None else np.ones(n, bool))
        luts = jnp.asarray(adc_tables(self.cb, queries))
        kk = k * max(1, rerank)
        s, i = adc_scan_ref(luts, jnp.asarray(self.codes), valid, min(kk, n))
        s, i = np.asarray(s), np.asarray(i)
        if rerank <= 1 or self.vectors is None:
            return s[:, :k], i[:, :k].astype(np.int64)
        out_s = np.full((queries.shape[0], k), -np.inf, np.float32)
        out_i = np.full((queries.shape[0], k), -1, np.int64)
        for r in range(queries.shape[0]):
            cand = i[r][i[r] >= 0]
            if len(cand) == 0:
                continue
            vc = self.vectors[cand]
            ip = vc @ queries[r]
            if self.cb.metric == "l2":
                sc = 2 * ip - (vc * vc).sum(1) - queries[r] @ queries[r]
            else:
                sc = ip
            top = np.argsort(-sc, kind="stable")[:k]
            out_s[r, : len(top)] = sc[top]
            out_i[r, : len(top)] = cand[top]
        return out_s, out_i

    @property
    def compression_ratio(self) -> float:
        d = self.cb.m * self.cb.dsub
        return (d * 4) / self.cb.m
