"""HQI core — the paper's contribution (workload-aware hybrid vector search).

Public API:
    VectorDatabase, Column, Workload, HybridQuery, SearchResult
    predicates: Cmp, Between, In, Contains, NotNull, CentroidIn, make_filter
    HQIIndex / HQIConfig — workload-aware index + Algorithm-3 batch search
    baselines: exhaustive_search, PreFilterIndex, PostFilterIndex, RangeIndex
    metrics: recall_at_k, tune_nprobe
"""
from .types import (  # noqa: F401
    Column,
    HybridQuery,
    METRIC_IP,
    METRIC_L2,
    SearchResult,
    VectorDatabase,
    Workload,
)
from .predicates import (  # noqa: F401
    Between,
    CentroidIn,
    Cmp,
    Contains,
    In,
    NotNull,
    evaluate_filter,
    make_filter,
)
from .qdtree import QDTree, build_qdtree  # noqa: F401
from .ivf import IVFIndex, ScanStats  # noqa: F401
from .hqi import HQIConfig, HQIIndex  # noqa: F401
from .baselines import (  # noqa: F401
    PostFilterIndex,
    PreFilterIndex,
    RangeIndex,
    exhaustive_search,
)
from .metrics import per_template_recall, recall_at_k, tune_nprobe  # noqa: F401
from .workload import kg_style, lp_style, synthetic_bigann_style  # noqa: F401
