"""HQI core — the paper's contribution (workload-aware hybrid vector search).

Public API:
    VectorDatabase, Column, Workload, HybridQuery, SearchResult
    predicates: Cmp, Between, In, Contains, NotNull, CentroidIn, make_filter
    HQIIndex / HQIConfig / Router — workload-aware index + Algorithm-3 search
    engine: PackedArena, PlanConfig, EngineTask, ExecutionPlan,
            build_plan / execute_plan, batch_search_ivf
    sharded engine: ShardedArena (PackedArena.shard), ShardedPlan /
            build_plan_sharded / execute_plan_sharded, ShardStats
            (mesh entry: core.distributed.execute_sharded / ShardSpec)
    compression: PQCodebook / PQIndex, train_pq / encode_pq / adc_tables
            (engine integration via PlanConfig.scan_mode="pq")
    baselines: exhaustive_search, PreFilterIndex, PostFilterIndex, RangeIndex
    metrics: recall_at_k, tune_nprobe
"""
from .types import (  # noqa: F401
    Column,
    HybridQuery,
    METRIC_IP,
    METRIC_L2,
    SearchResult,
    VectorDatabase,
    Workload,
)
from .predicates import (  # noqa: F401
    Between,
    CentroidIn,
    Cmp,
    Contains,
    In,
    NotNull,
    evaluate_filter,
    make_filter,
)
from .qdtree import QDTree, build_qdtree  # noqa: F401
from .ivf import IVFIndex, ScanStats  # noqa: F401
from .pq import PQCodebook, PQIndex, adc_tables, encode_pq, train_pq  # noqa: F401
from .arena import PackedArena, ShardedArena  # noqa: F401
from .plan import (  # noqa: F401
    EngineTask,
    ExecutionPlan,
    PlanConfig,
    ShardedPlan,
    build_plan,
    build_plan_sharded,
)
from .planner import (  # noqa: F401
    ShardStats,
    batch_search_ivf,
    execute_plan,
    execute_plan_sharded,
)
from .hqi import HQIConfig, HQIIndex, Router  # noqa: F401
from .baselines import (  # noqa: F401
    PostFilterIndex,
    PreFilterIndex,
    RangeIndex,
    exhaustive_search,
)
from .metrics import per_template_recall, recall_at_k, tune_nprobe  # noqa: F401
from .workload import kg_style, lp_style, synthetic_bigann_style  # noqa: F401
