"""whisper-large-v3 [audio] — enc-dec (arXiv:2212.04356). Conv frontend is a

STUB: input_specs() provides precomputed frame embeddings [B, 1500, d]."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,  # decoder layers
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, encoder_frames=16, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, q_chunk=32, kv_chunk=32,
    )
