"""zamba2-2.7b [hybrid] — Mamba2 backbone + one weight-shared attention block

applied every 6 SSD layers (arXiv:2411.15242; hf). long_500k RUNS."""
from ..models.ssm import SSMConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_model=2560, d_state=64, head_dim=64, expand=2, chunk=256),
    hybrid_attn_every=6,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256,
        ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2, chunk=32),
        hybrid_attn_every=2, q_chunk=32, kv_chunk=32,
    )
