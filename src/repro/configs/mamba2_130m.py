"""mamba2-130m [ssm] — attention-free SSD (arXiv:2405.21060).

d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads, d_state 128.
long_500k RUNS (O(1) state per token)."""
from ..models.ssm import SSMConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,  # unused for ssm family (SSD heads live in SSMConfig)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_model=768, d_state=128, head_dim=64, expand=2, chunk=256),
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2, chunk=32),
    )
