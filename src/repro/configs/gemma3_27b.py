"""gemma3-27b [dense] — 5:1 local:global attention, 128k context

(hf:google/gemma-3-*). Sliding window 1024 on local layers."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    rope_theta=1e6,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),  # 5 local : 1 global
    embed_scale=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window_pattern=(16, 16, 16, 16, 16, 0),
        q_chunk=32, kv_chunk=32,
    )
