"""qwen3-32b [dense] — GQA kv=8 with per-head q/k RMSNorm (hf:Qwen/Qwen3-*)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    rope_theta=1e6,
    qk_norm=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, q_chunk=32, kv_chunk=32,
    )
