"""internvl2-2b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821; hf).

Backbone only: the vision frontend is a stub; input_specs() provides
precomputed patch embeddings [B, 256, d_model] prepended to the tokens.
"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    rope_theta=1e6,
    vision_patches=256,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, vision_patches=8, q_chunk=32, kv_chunk=32,
    )
