"""qwen1.5-110b [dense] — GQA kv=8 with QKV bias (hf:Qwen/Qwen1.5-*)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    rope_theta=1e6,
    qkv_bias=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, q_chunk=32, kv_chunk=32,
    )
