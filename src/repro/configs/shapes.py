"""The four assigned input shapes. ``decode_*`` / ``long_*`` lower

``serve_step`` (one token against a seq_len KV cache), not ``train_step``."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# long_500k requires sub-quadratic attention (run for ssm/hybrid only).
LONG_OK_FAMILIES = ("ssm", "hybrid")


def shapes_for(family: str):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if family in LONG_OK_FAMILIES:
        out.append("long_500k")
    return out
