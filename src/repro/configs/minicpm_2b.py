"""minicpm-2b [dense] — llama-like, trained with the WSD schedule

(arXiv:2404.06395; hf). Its config selects the WSD optimizer schedule."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
)

SCHEDULE = "wsd"  # warmup-stable-decay (the paper's training schedule)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, q_chunk=32, kv_chunk=32,
    )
