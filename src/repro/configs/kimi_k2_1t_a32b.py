"""kimi-k2-1t-a32b [moe] — trillion-param MoE: 384 routed experts top-8 +

1 shared, 61 layers, first layer dense (arXiv:2501.kimi2 per assignment).
Expert FFN width 2048 (fine-grained); dense layer 0 uses a wide FFN."""
from ..models.moe import MoEConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,  # the leading dense layer's FFN
    vocab=163840,
    rope_theta=5e6,
    moe=MoEConfig(
        n_experts=384, top_k=8, d_ff_expert=2048,
        n_shared_experts=1, d_ff_shared=2048, capacity_factor=1.25,
    ),
    moe_first_dense=1,
)

OPTIMIZER = "adafactor"  # 1T params: factored second moment is mandatory


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1,
                      d_ff_shared=32),
        moe_first_dense=1, q_chunk=32, kv_chunk=32,
    )
