"""Config registry: one module per assigned architecture (+ HQI's own).

get_config(arch_id) -> full ModelConfig; get_reduced(arch_id) -> smoke-test
config of the same family wiring.
"""
from importlib import import_module

ARCHS = {
    "internvl2-2b": "internvl2_2b",
    "minicpm-2b": "minicpm_2b",
    "gemma3-27b": "gemma3_27b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-110b": "qwen1_5_110b",
    "mamba2-130m": "mamba2_130m",
    "whisper-large-v3": "whisper_large_v3",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "zamba2-2.7b": "zamba2_2p7b",
}


def _module(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return import_module(f".{ARCHS[arch_id]}", __package__)


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str):
    return _module(arch_id).reduced()


def optimizer_for(arch_id: str) -> str:
    return getattr(_module(arch_id), "OPTIMIZER", "adamw")


def schedule_for(arch_id: str) -> str:
    return getattr(_module(arch_id), "SCHEDULE", "cosine")
