"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed top-6,

first layer dense (arXiv:2401.06066; hf)."""
from ..models.moe import MoEConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # the leading dense layer's FFN
    vocab=102400,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_ff_expert=1408,
        n_shared_experts=2, d_ff_shared=2816, capacity_factor=1.25,
    ),
    moe_first_dense=1,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=2,
                      d_ff_shared=64),
        moe_first_dense=1, q_chunk=32, kv_chunk=32,
    )
