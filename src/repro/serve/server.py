"""Batched serving loop: continuous batching over a fixed-slot decode batch.

A slot-based scheduler (vLLM-style, TPU-static-shapes flavor): the decode
step always runs the full [B_slots] batch; finished/empty slots are masked.
New requests prefill individually (or in small groups) and their KV is
inserted into a free slot. This keeps every compiled shape static — the TPU
requirement — while reaching high slot occupancy under load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import api
from ..models.transformer import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 [S]
    max_new_tokens: int = 32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotServer:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8, max_len: int = 512, eos_id: int = 1):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = api.init_cache(cfg, n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_budget = np.zeros(n_slots, dtype=np.int64)
        self._decode = jax.jit(lambda p, t, c: api.serve_decode(p, cfg, t, c))
        self._last_token = np.zeros(n_slots, dtype=np.int32)

    # -- admission -------------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Prefill the request and insert its KV into a free slot."""
        slot = self._free_slot()
        if slot is None:
            return False
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, cache1 = api.serve_prefill(self.params, self.cfg, {"tokens": toks}, max_len=self.max_len)
        # write the single-row cache into the slot
        def insert(dst, src):
            if dst.ndim < 2 or dst.shape[1] != self.n_slots:
                # leading layer/group dim then batch
                bdim = next(i for i, d in enumerate(dst.shape) if d == self.n_slots)
            else:
                bdim = 1
            idx = [slice(None)] * dst.ndim
            idx[bdim] = slice(slot, slot + 1)
            pad = [(0, d1 - d2) for d1, d2 in zip(dst[tuple(idx)].shape, src.shape)]
            src = jnp.pad(src, pad)
            return dst.at[tuple(idx)].set(src.astype(dst.dtype))

        self.cache = jax.tree.map(insert, self.cache, cache1)
        tok = int(jnp.argmax(logits[0]))
        req.out_tokens.append(tok)
        self._last_token[slot] = tok
        self.slot_req[slot] = req
        self.slot_budget[slot] = req.max_new_tokens - 1
        return True

    # -- decode tick -------------------------------------------------------------

    def tick(self):
        """One decode step for every occupied slot."""
        if all(r is None for r in self.slot_req):
            return
        toks = jnp.asarray(self._last_token, jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self._last_token[slot] = tok
            self.slot_budget[slot] -= 1
            if tok == self.eos_id or self.slot_budget[slot] <= 0:
                req.done = True
                self.slot_req[slot] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000) -> List[Request]:
        pending = list(requests)
        for _ in range(max_ticks):
            while pending and self._free_slot() is not None:
                self.admit(pending.pop(0))
            if not pending and all(r is None for r in self.slot_req):
                break
            self.tick()
        return requests
